//! `invariant-lint` — a dependency-free static checker for the
//! serving subsystem's concurrency invariants.
//!
//! This is a deliberately *line-oriented* scanner (string-stripping +
//! brace-depth tracking, no syn/proc-macro, no external crates): the
//! rules it enforces are lexical properties of the code, chosen so
//! that a heuristic scanner can check them soundly.  It walks
//! `rust/src/coordinator/serving/**` and enforces:
//!
//! | rule id                  | invariant                                      |
//! |--------------------------|------------------------------------------------|
//! | `raw-mutex`              | no raw `std::sync::{Mutex,RwLock,Condvar}` in  |
//! |                          | serving — every lock is a ranked one (sync.rs) |
//! | `ordering-allowlist`     | every atomic `Ordering::X` named in a file is  |
//! |                          | in that file's allowlist below, so `SeqCst`    |
//! |                          | creep needs a written rationale                |
//! | `guard-across-execute`   | no lock guard live across `Executor::execute`  |
//! |                          | or `catch_unwind` — a panicking backend must   |
//! |                          | never poison a held serving lock               |
//! | `terminal-outside-channel`| `StreamEvent::Done`/`Shed` only appear in the |
//! |                          | channel module (`stream/mod.rs`) — the         |
//! |                          | exactly-once terminal discipline has one home  |
//! | `trace-confined`         | `TraceEvent` construction only appears in the  |
//! |                          | recorder module (`trace.rs`) — emission goes   |
//! |                          | through the typed API so the ledger counts it  |
//! | `stale-allow`            | every `lint: allow` escape suppresses a real   |
//! |                          | finding (dead escapes rot into folklore)       |
//!
//! Escapes: `// lint: allow(<rule>) — <reason>` on the offending line,
//! or alone on the line above it.  Every escape is inventoried by
//! `invariant-lint --list-allows` so reviewers see the exception
//! budget per PR, and an escape that stops matching anything is itself
//! a finding (`stale-allow`).
//!
//! The binary wrapper lives in `src/bin/invariant_lint.rs`; the tests
//! in `rust/tests/invariant_lint.rs` drive [`scan_source`] directly
//! over the fixture files in `rust/tests/lint_fixtures/`.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

pub const RULE_RAW_MUTEX: &str = "raw-mutex";
pub const RULE_ORDERING: &str = "ordering-allowlist";
pub const RULE_GUARD_ACROSS_EXECUTE: &str = "guard-across-execute";
pub const RULE_TERMINAL_OUTSIDE_CHANNEL: &str = "terminal-outside-channel";
pub const RULE_TRACE_CONFINED: &str = "trace-confined";
pub const RULE_STALE_ALLOW: &str = "stale-allow";

const ALL_RULES: &[&str] = &[
    RULE_RAW_MUTEX,
    RULE_ORDERING,
    RULE_GUARD_ACROSS_EXECUTE,
    RULE_TERMINAL_OUTSIDE_CHANNEL,
    RULE_TRACE_CONFINED,
    RULE_STALE_ALLOW,
];

/// Per-file atomic-`Ordering` allowlist: `(path suffix, allowed
/// orderings, rationale)`.  A serving file that names an `Ordering`
/// variant absent from its row — or that has no row at all — fails
/// `ordering-allowlist`; widening a row therefore requires editing
/// this table and writing the justification next to it, which is the
/// point.
pub const ORDERING_ALLOWLIST: &[(&str, &[&str], &str)] = &[
    (
        "coordinator/serving/queue.rs",
        &["Relaxed", "SeqCst"],
        "SeqCst is load-bearing twice: the deposit_reserved <-> pop \
         exit-time depth re-check handshake, and the Dekker-style \
         sleepers-vs-ready doorbell fast path — both need the single \
         total order.  Relaxed covers the advisory per-shard gauges \
         and tick counters.",
    ),
    (
        "coordinator/serving/mod.rs",
        &["Relaxed", "AcqRel"],
        "AcqRel is the Arc-style live-worker refcount (release own \
         work on decrement, acquire everyone's on the last-out close); \
         everything else is statistics read after a join or a latch \
         round-trip.",
    ),
    (
        "coordinator/serving/worker.rs",
        &["Relaxed"],
        "fault-ladder counters: pure statistics, aggregated at \
         shutdown after the worker threads are joined.",
    ),
    (
        "coordinator/serving/stream/mod.rs",
        &["Relaxed"],
        "session-id allocator and session/step counters: uniqueness \
         needs only atomicity, and the counters are read at shutdown \
         after joins.",
    ),
    (
        "coordinator/serving/stream/arena.rs",
        &["Relaxed"],
        "hit/miss/recycle gauges: statistics only; the page pool \
         itself is behind the ArenaPool-ranked mutex.",
    ),
    (
        "coordinator/serving/stream/spec.rs",
        &["Relaxed"],
        "speculative counters are all bumped inside one verify \
         resolution and read at shutdown after joins; the \
         drafted == accepted + rejected invariant is single-writer \
         per session.",
    ),
    (
        "coordinator/serving/trace.rs",
        &["Relaxed"],
        "flight-recorder ledger (emitted/dropped/exported) and the \
         trace-id allocator: the ledger is reconciled only after \
         drain() — itself behind the TraceRing-ranked lane locks — \
         and the allocator needs only uniqueness.",
    ),
];

const ATOMIC_ORDERINGS: &[&str] =
    &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// One lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-indexed
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule,
               self.msg)
    }
}

/// One `// lint: allow(rule) — reason` escape found in a file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    pub file: String,
    /// 1-indexed line of the comment itself
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

impl fmt::Display for Allow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: allow({}) — {}", self.file, self.line,
               self.rule,
               if self.reason.is_empty() { "(no reason given)" }
               else { &self.reason })
    }
}

/// Scanner output for one file.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub allows: Vec<Allow>,
}

/// Is this file subject to the serving rules at all?
fn in_scope(rel_path: &str) -> bool {
    rel_path.contains("coordinator/serving/")
        && rel_path.ends_with(".rs")
}

/// Strips comments and blanks out string/char-literal contents, so
/// the rule passes only ever see real code tokens.  Returns
/// `(code, comment)` per line; multi-line strings and block comments
/// carry state across lines via `self`.
#[derive(Default)]
struct Sanitizer {
    in_block_comment: bool,
    in_string: bool,
}

impl Sanitizer {
    /// One line in, `(code-with-literals-blanked, comment-text)` out.
    fn split(&mut self, line: &str) -> (String, String) {
        let bytes: Vec<char> = line.chars().collect();
        let mut code = String::with_capacity(line.len());
        let mut comment = String::new();
        let mut i = 0usize;
        while i < bytes.len() {
            if self.in_block_comment {
                if bytes[i] == '*'
                    && i + 1 < bytes.len()
                    && bytes[i + 1] == '/'
                {
                    self.in_block_comment = false;
                    i += 2;
                } else {
                    i += 1;
                }
                code.push(' ');
                continue;
            }
            if self.in_string {
                if bytes[i] == '\\' {
                    i += 2; // escape: skip the escaped char too
                    code.push(' ');
                    continue;
                }
                if bytes[i] == '"' {
                    self.in_string = false;
                    code.push('"');
                } else {
                    code.push(' ');
                }
                i += 1;
                continue;
            }
            match bytes[i] {
                '/' if i + 1 < bytes.len() && bytes[i + 1] == '/' => {
                    // line comment: the rest of the line is comment
                    comment = bytes[i..].iter().collect();
                    break;
                }
                '/' if i + 1 < bytes.len() && bytes[i + 1] == '*' => {
                    self.in_block_comment = true;
                    code.push(' ');
                    i += 2;
                }
                '"' => {
                    self.in_string = true;
                    code.push('"');
                    i += 1;
                }
                '\'' => {
                    // char literal vs lifetime: 'x' or '\n' is a
                    // literal (blank it), 'a as in <'a> is a lifetime
                    // (keep scanning)
                    if i + 2 < bytes.len()
                        && bytes[i + 1] != '\\'
                        && bytes[i + 2] == '\''
                    {
                        code.push_str("   ");
                        i += 3;
                    } else if i + 3 < bytes.len()
                        && bytes[i + 1] == '\\'
                        && bytes[i + 3] == '\''
                    {
                        code.push_str("    ");
                        i += 4;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                }
                c => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        (code, comment)
    }
}

/// Find every identifier-boundary occurrence of `word` in `code`.
fn word_positions(code: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let code_b = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || {
            let c = code_b[at - 1] as char;
            !(c.is_alphanumeric() || c == '_')
        };
        let end = at + word.len();
        let after_ok = end >= code.len() || {
            let c = code_b[end] as char;
            !(c.is_alphanumeric() || c == '_')
        };
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

/// A `lint: allow` escape parsed out of a comment, pre-resolution.
struct PendingAllow {
    line: usize,
    rule: String,
    reason: String,
    /// line number the allow suppresses findings on (its own line if
    /// inline, the next code line if the comment stands alone)
    target: usize,
    used: bool,
}

fn parse_allow(comment: &str, line: usize, own_code_empty: bool)
               -> Option<PendingAllow> {
    let at = comment.find("lint: allow(")?;
    let rest = &comment[at + "lint: allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let reason = rest[close + 1..]
        .trim_start_matches([' ', '—', '-', '–', ':'])
        .trim()
        .to_string();
    Some(PendingAllow {
        line,
        rule,
        reason,
        // resolved properly (next code line) by the caller when the
        // comment stands alone
        target: if own_code_empty { line + 1 } else { line },
        used: false,
    })
}

/// Scan one file's source.  `rel_path` is the path relative to the
/// scan root with forward slashes (e.g.
/// `coordinator/serving/queue.rs`); it selects rule applicability and
/// the ordering allowlist row.  Out-of-scope files produce an empty
/// report.
pub fn scan_source(rel_path: &str, source: &str) -> FileReport {
    let mut report = FileReport::default();
    if !in_scope(rel_path) {
        return report;
    }
    let is_channel_module = rel_path.ends_with("stream/mod.rs");
    let is_recorder_module = rel_path.ends_with("serving/trace.rs");
    let ordering_row = ORDERING_ALLOWLIST
        .iter()
        .find(|(suffix, _, _)| rel_path.ends_with(suffix));

    // pass 1: sanitize every line, collect allows
    let mut sanitizer = Sanitizer::default();
    let mut code_lines: Vec<String> = Vec::new();
    let mut allows: Vec<PendingAllow> = Vec::new();
    for (idx, raw) in source.lines().enumerate() {
        let line_no = idx + 1;
        let (code, comment) = sanitizer.split(raw);
        if let Some(a) =
            parse_allow(&comment, line_no, code.trim().is_empty())
        {
            allows.push(a);
        }
        code_lines.push(code);
    }
    // a standalone allow targets the next line that has code on it
    for a in &mut allows {
        if a.target > a.line {
            let mut t = a.line; // 0-indexed successor of a.line - 1
            while t < code_lines.len()
                && code_lines[t].trim().is_empty()
            {
                t += 1;
            }
            a.target = t + 1;
        }
    }

    // findings are buffered through the allow filter
    let emit = |allows: &mut Vec<PendingAllow>, line: usize,
                    rule: &'static str, msg: String,
                    findings: &mut Vec<Finding>| {
        for a in allows.iter_mut() {
            if a.target == line && a.rule == rule {
                a.used = true;
                return;
            }
        }
        findings.push(Finding {
            file: rel_path.to_string(),
            line,
            rule,
            msg,
        });
    };

    // pass 2: the per-line rules plus the guard-liveness tracker
    let mut depth: i64 = 0;
    // (binding name, depth at bind, bind line)
    let mut live_guards: Vec<(String, i64, usize)> = Vec::new();
    for (idx, code) in code_lines.iter().enumerate() {
        let line_no = idx + 1;

        // rule: raw-mutex — serving code locks through sync.rs only
        for word in ["Mutex", "RwLock", "Condvar"] {
            if !word_positions(code, word).is_empty() {
                emit(&mut allows, line_no, RULE_RAW_MUTEX,
                     format!(
                         "raw std::sync::{word} in serving code — use \
                          the ranked wrapper from crate::sync (rank \
                          table enforces the lock order)"),
                     &mut report.findings);
                break; // one finding per line is enough
            }
        }

        // rule: ordering-allowlist — every named atomic ordering must
        // be allowlisted for this file
        for at in word_positions(code, "Ordering") {
            let rest = &code[at + "Ordering".len()..];
            let Some(variant) = rest.strip_prefix("::") else {
                continue;
            };
            let variant: String = variant
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !ATOMIC_ORDERINGS.contains(&variant.as_str()) {
                continue; // std::cmp::Ordering::Less etc.
            }
            match ordering_row {
                None => {
                    emit(&mut allows, line_no, RULE_ORDERING,
                         format!(
                             "atomic Ordering::{variant} in a file \
                              with no ORDERING_ALLOWLIST row — add \
                              one in lint.rs with a rationale"),
                         &mut report.findings);
                }
                Some((_, allowed, _)) => {
                    if !allowed.contains(&variant.as_str()) {
                        emit(&mut allows, line_no, RULE_ORDERING,
                             format!(
                                 "Ordering::{variant} is not in this \
                                  file's allowlist {allowed:?} — \
                                  justify it in lint.rs or use the \
                                  documented weaker ordering"),
                             &mut report.findings);
                    }
                }
            }
        }

        // rule: terminal-outside-channel — Done/Shed construction has
        // exactly one home
        if !is_channel_module {
            for word in ["StreamEvent::Done", "StreamEvent::Shed"] {
                if code.contains(word) {
                    emit(&mut allows, line_no,
                         RULE_TERMINAL_OUTSIDE_CHANNEL,
                         format!(
                             "{word} outside stream/mod.rs — terminal \
                              events are constructed only by the \
                              channel module (exactly-once \
                              discipline)"),
                         &mut report.findings);
                    break;
                }
            }
        }

        // rule: trace-confined — TraceEvent construction has exactly
        // one home: the recorder API stamps, counts and ring-buffers
        // every event, so an event built elsewhere would dodge the
        // dropped + exported == emitted ledger
        if !is_recorder_module && code.contains("TraceEvent::") {
            emit(&mut allows, line_no, RULE_TRACE_CONFINED,
                 "TraceEvent constructed outside serving/trace.rs — \
                  emit through the TraceRecorder methods so the event \
                  is stamped and counted by the ledger"
                     .to_string(),
                 &mut report.findings);
        }

        // rule: guard-across-execute — positional event walk so
        // `{{ let g = m.lock(); }}` one-liners scope correctly
        #[derive(PartialEq)]
        enum Ev {
            Open,
            Close,
            Drop(String),
            Exec,
            Bind(String),
        }
        let mut events: Vec<(usize, Ev)> = Vec::new();
        for (pos, c) in code.char_indices() {
            if c == '{' {
                events.push((pos, Ev::Open));
            } else if c == '}' {
                events.push((pos, Ev::Close));
            }
        }
        let mut from = 0usize;
        while let Some(p) = code[from..].find("drop(") {
            let at = from + p;
            let name: String = code[at + "drop(".len()..]
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                events.push((at, Ev::Drop(name)));
            }
            from = at + 1;
        }
        for needle in [".execute(", "catch_unwind"] {
            let mut from = 0usize;
            while let Some(p) = code[from..].find(needle) {
                events.push((from + p, Ev::Exec));
                from = from + p + 1;
            }
        }
        // a guard bind: `let [mut] name = <expr>.lock();` (or
        // .read()/.write(), with or without .unwrap()) — value binds
        // like `let x = m.lock().pop();` hold no guard and don't match
        let trimmed = code.trim_end();
        let is_guard_stmt = ["lock()", "read()", "write()"]
            .iter()
            .any(|m| {
                trimmed.ends_with(&format!(".{m};"))
                    || trimmed.ends_with(&format!(".{m}.unwrap();"))
            });
        if is_guard_stmt {
            if let Some(let_at) = word_positions(code, "let").first() {
                let name: String = code[let_at + "let".len()..]
                    .trim_start()
                    .trim_start_matches("mut ")
                    .trim_start()
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !name.is_empty() {
                    events.push((*let_at, Ev::Bind(name)));
                }
            }
        }
        events.sort_by_key(|(pos, _)| *pos);
        for (_, ev) in events {
            match ev {
                Ev::Open => depth += 1,
                Ev::Close => {
                    depth -= 1;
                    live_guards.retain(|(_, d, _)| *d <= depth);
                }
                Ev::Drop(name) => {
                    live_guards.retain(|(n, _, _)| *n != name);
                }
                Ev::Bind(name) => {
                    live_guards.push((name, depth, line_no));
                }
                Ev::Exec => {
                    if let Some((name, _, bound)) = live_guards.first()
                    {
                        emit(&mut allows, line_no,
                             RULE_GUARD_ACROSS_EXECUTE,
                             format!(
                                 "executor/catch_unwind call while \
                                  lock guard `{name}` (bound line \
                                  {bound}) is live — a panicking \
                                  backend would poison it; drop the \
                                  guard first"),
                             &mut report.findings);
                    }
                }
            }
        }
    }

    // pass 3: stale or unknown allows are themselves findings
    for a in &allows {
        if !ALL_RULES.contains(&a.rule.as_str()) {
            report.findings.push(Finding {
                file: rel_path.to_string(),
                line: a.line,
                rule: RULE_STALE_ALLOW,
                msg: format!(
                    "allow({}) names no known rule (known: {})",
                    a.rule,
                    ALL_RULES.join(", ")),
            });
        } else if !a.used {
            report.findings.push(Finding {
                file: rel_path.to_string(),
                line: a.line,
                rule: RULE_STALE_ALLOW,
                msg: format!(
                    "allow({}) suppresses nothing — the finding it \
                     excused is gone; delete the escape", a.rule),
            });
        }
    }
    report.allows = allows
        .into_iter()
        .map(|a| Allow {
            file: rel_path.to_string(),
            line: a.line,
            rule: a.rule,
            reason: a.reason,
        })
        .collect();
    report.findings.sort_by_key(|f| f.line);
    report
}

/// Recursively scan every `.rs` file under `root` (rule applicability
/// is decided per file from its relative path, so passing `rust/src`
/// lints exactly the serving subsystem).
pub fn scan_tree(root: &Path) -> io::Result<(Vec<Finding>, Vec<Allow>)> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    let mut allows = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        if !in_scope(&rel) {
            continue;
        }
        let source = fs::read_to_string(&path)?;
        let mut report = scan_source(&rel, &source);
        findings.append(&mut report.findings);
        allows.append(&mut report.allows);
    }
    Ok((findings, allows))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>)
                    -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
