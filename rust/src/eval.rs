//! Host-side evaluation metrics over executable outputs.
//!
//! Mirrors Appendix A's metrics exactly: ΔLM-loss, top-1 token prediction
//! agreement, plus the ViT cosine-similarity and the caption metrics that
//! `data::capgen` grounds.  Everything operates on flat row-major buffers
//! as returned by the PJRT runtime.

use anyhow::{bail, Result};

use crate::data::tokenizer::PAD;

/// Top-1 agreement between two logit tensors [B, T, V] on non-pad targets,
/// computed over *predictive* positions (logits at t predict targets[t+1]),
/// matching Appendix A.3.
pub fn top1_match(logits_a: &[f32], logits_b: &[f32], tokens: &[i32],
                  b: usize, t: usize, v: usize) -> Result<f64> {
    if logits_a.len() != b * t * v || logits_b.len() != b * t * v
        || tokens.len() != b * t {
        bail!("top1_match: shape mismatch");
    }
    let mut matched = 0usize;
    let mut total = 0usize;
    for bi in 0..b {
        for ti in 0..t - 1 {
            let target = tokens[bi * t + ti + 1];
            if target == PAD {
                continue;
            }
            let off = (bi * t + ti) * v;
            let am = argmax(&logits_a[off..off + v]);
            let bm = argmax(&logits_b[off..off + v]);
            total += 1;
            if am == bm {
                matched += 1;
            }
        }
    }
    Ok(if total == 0 { 1.0 } else { matched as f64 / total as f64 })
}

/// Next-token cross-entropy of logits [B, T, V] against tokens (pad-masked);
/// the host-side mirror of `losses.cross_entropy` (used to cross-check the
/// in-graph loss outputs).
pub fn cross_entropy(logits: &[f32], tokens: &[i32], b: usize, t: usize,
                     v: usize) -> Result<f64> {
    if logits.len() != b * t * v || tokens.len() != b * t {
        bail!("cross_entropy: shape mismatch");
    }
    let mut total = 0.0f64;
    let mut count = 0usize;
    for bi in 0..b {
        for ti in 0..t - 1 {
            let target = tokens[bi * t + ti + 1];
            if target == PAD {
                continue;
            }
            let row = &logits[(bi * t + ti) * v..(bi * t + ti + 1) * v];
            total += -log_softmax_at(row, target as usize);
            count += 1;
        }
    }
    Ok(if count == 0 { 0.0 } else { total / count as f64 })
}

fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum::<f64>().ln() + mx;
    row[idx] as f64 - lse
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

/// Greedy next token at position `pos` of sequence `bi` from logits [B,T,V].
pub fn greedy_token(logits: &[f32], bi: usize, pos: usize, t: usize,
                    v: usize) -> i32 {
    argmax(&logits[(bi * t + pos) * v..(bi * t + pos + 1) * v]) as i32
}

/// Mean cosine similarity between two [N, D] token-embedding buffers,
/// averaged over rows (the Fig. 7 / Fig. 8 metric).
pub fn mean_cosine(a: &[f32], b: &[f32], n: usize, d: usize) -> Result<f64> {
    if a.len() != n * d || b.len() != n * d {
        bail!("mean_cosine: shape mismatch");
    }
    let mut acc = 0.0f64;
    for i in 0..n {
        let (x, y) = (&a[i * d..(i + 1) * d], &b[i * d..(i + 1) * d]);
        let dot: f64 = x.iter().zip(y).map(|(p, q)| (*p as f64) * (*q as f64)).sum();
        let nx: f64 = x.iter().map(|p| (*p as f64).powi(2)).sum::<f64>().sqrt();
        let ny: f64 = y.iter().map(|p| (*p as f64).powi(2)).sum::<f64>().sqrt();
        acc += if nx * ny > 0.0 { dot / (nx * ny) } else { 0.0 };
    }
    Ok(acc / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_match_identical_is_one() {
        let (b, t, v) = (1, 3, 4);
        let logits = vec![0.1, 0.9, 0.0, 0.0,
                          0.0, 0.0, 1.0, 0.0,
                          0.5, 0.0, 0.0, 0.0];
        let tokens = vec![3, 1, 2];
        let m = top1_match(&logits, &logits, &tokens, b, t, v).unwrap();
        assert_eq!(m, 1.0);
    }

    #[test]
    fn top1_match_ignores_pad() {
        let (b, t, v) = (1, 3, 2);
        let a = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let c = vec![0.0, 1.0, 1.0, 0.0, 1.0, 0.0];
        // target at pos1 = tokens[2] = PAD -> only pos0 counts
        let tokens = vec![3, 4, 0];
        let m = top1_match(&a, &c, &tokens, b, t, v).unwrap();
        assert_eq!(m, 0.0);
    }

    #[test]
    fn cross_entropy_matches_uniform() {
        let (b, t, v) = (1, 2, 4);
        let logits = vec![0.0; b * t * v];
        let tokens = vec![3, 2];
        let ce = cross_entropy(&logits, &tokens, b, t, v).unwrap();
        assert!((ce - (4.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn cosine_perfect_and_orthogonal() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 0.0, 0.0, 3.0];
        assert!((mean_cosine(&a, &b, 2, 2).unwrap() - 1.0).abs() < 1e-9);
        let c = vec![0.0, 1.0, 1.0, 0.0];
        assert!(mean_cosine(&a, &c, 2, 2).unwrap().abs() < 1e-9);
    }

    #[test]
    fn shape_validation() {
        assert!(top1_match(&[0.0; 4], &[0.0; 4], &[0; 3], 1, 2, 2).is_err());
        assert!(mean_cosine(&[0.0; 4], &[0.0; 5], 2, 2).is_err());
    }

    #[test]
    fn greedy_token_picks_argmax() {
        let logits = vec![0.0, 3.0, 1.0,   2.0, 0.0, 1.0];
        assert_eq!(greedy_token(&logits, 0, 0, 2, 3), 1);
        assert_eq!(greedy_token(&logits, 0, 1, 2, 3), 0);
    }
}
