//! Minimal property-testing harness (`proptest` is not in the vendored
//! crate set).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs drawn through the given closure; on failure it retries with the
//! recorded seed to confirm, then panics with the reproducing seed so the
//! failure is one `Rng::new(seed)` away.  Used by the coordinator-invariant
//! tests (batcher, capacity controller, tokenizer, JSON round-trip).

use crate::rng::Rng;

/// Run `prop` over `cases` seeded inputs; panics with the failing seed.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // fixed master seed => deterministic CI; distinct per property name
    let mut master = Rng::new(0xE1A5_71F0_u64 ^ hash_name(name));
    for case in 0..cases {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            // confirm reproducibility before reporting
            let mut rng2 = Rng::new(seed);
            let msg2 = prop(&mut rng2).err().unwrap_or_else(|| {
                "WARNING: failure did not reproduce (flaky property?)".into()
            });
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  \
                 {msg}\n  reproduce: Rng::new({seed:#x}) — confirmed: {msg2}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Helper: assert with formatted message inside a property.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always_true", 25, |rng| {
            count += 1;
            let x = rng.below(10);
            if x < 10 { Ok(()) } else { Err("impossible".into()) }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property \"always_false\" failed")]
    fn failing_property_panics_with_seed() {
        check("always_false", 5, |_rng| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen1 = Vec::new();
        check("record1", 5, |rng| {
            seen1.push(rng.next_u64());
            Ok(())
        });
        let mut seen2 = Vec::new();
        check("record1", 5, |rng| {
            seen2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }
}
