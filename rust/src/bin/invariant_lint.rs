//! Binary wrapper for the serving-subsystem invariant linter (the
//! scanner itself is `elastiformer::lint`, so the test harness in
//! `rust/tests/invariant_lint.rs` can drive it as a library).
//!
//! Usage:
//!
//! ```text
//! cargo run --bin invariant-lint -- rust/src                # gate (CI)
//! cargo run --bin invariant-lint -- --list-allows rust/src  # escape audit
//! ```
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::Path;
use std::process::ExitCode;

use elastiformer::lint;

fn main() -> ExitCode {
    let mut list_allows = false;
    let mut root: Option<String> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--list-allows" => list_allows = true,
            "--help" | "-h" => {
                println!(
                    "invariant-lint [--list-allows] [ROOT]\n\
                     scan ROOT (default rust/src) for serving-subsystem \
                     concurrency-invariant violations");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("invariant-lint: unknown flag {other}");
                return ExitCode::from(2);
            }
            other => root = Some(other.to_string()),
        }
    }
    let root = root.unwrap_or_else(|| {
        // default works from the workspace root; fall back to the
        // crate dir so `cargo run` from rust/ also just works
        if Path::new("rust/src").is_dir() {
            "rust/src".to_string()
        } else {
            "src".to_string()
        }
    });
    let root = Path::new(&root);
    if !root.is_dir() {
        eprintln!("invariant-lint: {} is not a directory",
                  root.display());
        return ExitCode::from(2);
    }
    let (findings, allows) = match lint::scan_tree(root) {
        Ok(out) => out,
        Err(e) => {
            eprintln!("invariant-lint: scanning {}: {e}",
                      root.display());
            return ExitCode::from(2);
        }
    };
    if list_allows {
        // the exception budget: every escape with file/line/reason,
        // uploadable as a CI artifact for per-PR review
        for a in &allows {
            println!("{a}");
        }
        println!("{} allow escape(s)", allows.len());
        return ExitCode::SUCCESS;
    }
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!(
            "invariant-lint: clean ({} allow escape(s) in force — \
             run --list-allows for the audit)", allows.len());
        ExitCode::SUCCESS
    } else {
        println!("invariant-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
