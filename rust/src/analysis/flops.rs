//! Analytic compute model of the transformer stack.
//!
//! The sandbox runs interpret-mode Pallas on CPU, so the paper's compute
//! savings are reported analytically: this module maps a model config plus
//! a routing capacity vector to MACs (multiply-accumulates) per token and
//! active-parameter counts, the x-axes of Figures 5–7 and the Table 1 rows.

/// Model dimensions needed for compute accounting (read from the manifest).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelDims {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub n_experts: usize,
}

/// Routing capacities (fractions in (0, 1]); mirrors the caps vector the
/// elastic artifacts take.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Capacity {
    pub mha_tokens: f64,
    pub mlp_tokens: f64,
    pub heads: f64,
    pub experts: f64,
    /// fraction of layers routed (1.0 = all, 0.5 = even layers)
    pub layers: f64,
}

impl Capacity {
    pub fn full() -> Capacity {
        Capacity { mha_tokens: 1.0, mlp_tokens: 1.0, heads: 1.0,
                   experts: 1.0, layers: 1.0 }
    }

    pub fn uniform(c: f64) -> Capacity {
        Capacity { mha_tokens: c, mlp_tokens: c, heads: c, experts: c,
                   layers: 1.0 }
    }
}

/// MACs per *sequence* for the dense teacher.
pub fn teacher_macs(d: &ModelDims) -> u64 {
    let t = d.seq_len as u64;
    let dm = d.d_model as u64;
    let ff = d.d_ff as u64;
    let per_layer_proj = 4 * t * dm * dm;          // q,k,v,o projections
    let per_layer_attn = 2 * t * t * dm;           // QK^T + AV (all heads)
    let per_layer_mlp = 2 * t * dm * ff;           // up + down
    d.n_layers as u64 * (per_layer_proj + per_layer_attn + per_layer_mlp)
        + t * dm * d.vocab as u64                  // lm head
}

/// MACs per sequence for the elastic model at the given capacity.
///
/// Token routing shrinks the token dimension of the gated module; head /
/// expert routing shrinks the head / expert dimension.  Router overhead
/// (the tiny linear probes) is included.  Layers outside the routed subset
/// run dense.
pub fn elastic_macs(d: &ModelDims, c: &Capacity) -> u64 {
    let t = d.seq_len as f64;
    let dm = d.d_model as f64;
    let ff = d.d_ff as f64;
    let heads = d.n_heads as f64;
    let experts = d.n_experts as f64;

    let k_tok_mha = (c.mha_tokens * t).ceil().max(1.0);
    let k_tok_mlp = (c.mlp_tokens * t).ceil().max(1.0);
    let k_heads = (c.heads * heads).round().clamp(1.0, heads);
    let k_exp = (c.experts * experts).round().clamp(1.0, experts);

    // routed layer
    let proj = 4.0 * k_tok_mha * dm * dm * (k_heads / heads);
    let attn = 2.0 * k_tok_mha * k_tok_mha * dm * (k_heads / heads);
    let mlp = 2.0 * k_tok_mlp * dm * ff * (k_exp / experts);
    let routers = t * dm * (2.0 + heads + experts); // 2 token probes + 2 param routers
    let routed = proj + attn + mlp + routers;

    // dense layer
    let dense = 4.0 * t * dm * dm + 2.0 * t * t * dm + 2.0 * t * dm * ff;

    let n_routed = (c.layers * d.n_layers as f64).round();
    let n_dense = d.n_layers as f64 - n_routed;
    (n_routed * routed + n_dense * dense
        + t * dm * d.vocab as f64) as u64
}

/// Active parameters touched per token (the Fig. 5/7 x-axis variant).
pub fn active_params(d: &ModelDims, c: &Capacity) -> u64 {
    let dm = d.d_model as f64;
    let ff = d.d_ff as f64;
    let k_heads = (c.heads * d.n_heads as f64).round().max(1.0);
    let k_exp = (c.experts * d.n_experts as f64).round().max(1.0);

    let attn = 4.0 * dm * dm * (k_heads / d.n_heads as f64);
    let mlp = 2.0 * dm * ff * (k_exp / d.n_experts as f64);
    let routed = attn * c.mha_tokens + mlp * c.mlp_tokens;
    let dense = 4.0 * dm * dm + 2.0 * dm * ff;
    let n_routed = (c.layers * d.n_layers as f64).round();
    let n_dense = d.n_layers as f64 - n_routed;
    (n_routed * routed + n_dense * dense + dm * d.vocab as f64) as u64
}

/// Router parameter counts per routing family (the Table 1 formulas).
pub fn router_param_counts(d: &ModelDims) -> Vec<(&'static str, u64)> {
    let l = d.n_layers as u64;
    let dm = d.d_model as u64;
    vec![
        ("input/MLP  L*(D+1)", l * (dm + 1)),
        ("input/MHA  L*(D+1)", l * (dm + 1)),
        ("param/MLP  L*(D+1)*M", l * (dm + 1) * d.n_experts as u64),
        ("param/MHA  L*(D+1)*H", l * (dm + 1) * d.n_heads as u64),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { d_model: 128, n_layers: 4, n_heads: 4, d_ff: 512,
                    seq_len: 128, vocab: 256, n_experts: 8 }
    }

    #[test]
    fn full_capacity_close_to_teacher() {
        let d = dims();
        let t = teacher_macs(&d) as f64;
        let e = elastic_macs(&d, &Capacity::full()) as f64;
        // elastic at full capacity = teacher + router overhead (< 5%)
        assert!(e >= t);
        assert!(e / t < 1.05, "overhead ratio {}", e / t);
    }

    #[test]
    fn savings_monotone_in_capacity() {
        let d = dims();
        let mut prev = u64::MAX;
        for c in [1.0, 0.75, 0.5, 0.25] {
            let e = elastic_macs(&d, &Capacity::uniform(c));
            assert!(e < prev, "not monotone at {c}");
            prev = e;
        }
    }

    #[test]
    fn half_capacity_saves_roughly_half_of_big_terms() {
        let d = dims();
        let t = teacher_macs(&d) as f64;
        let e = elastic_macs(&d, &Capacity::uniform(0.5)) as f64;
        let ratio = e / t;
        assert!(ratio > 0.2 && ratio < 0.55, "ratio {ratio}");
    }

    #[test]
    fn even_layer_routing_halves_savings() {
        let d = dims();
        let full = elastic_macs(&d, &Capacity::uniform(0.5));
        let mut even = Capacity::uniform(0.5);
        even.layers = 0.5;
        let e = elastic_macs(&d, &even);
        let t = teacher_macs(&d);
        assert!(e > full && e < t);
    }

    #[test]
    fn active_params_bounds() {
        let d = dims();
        let full = active_params(&d, &Capacity::full());
        let quarter = active_params(&d, &Capacity::uniform(0.25));
        assert!(quarter < full);
        assert!(quarter > 0);
    }

    #[test]
    fn table1_formulas() {
        let d = dims();
        let rows = router_param_counts(&d);
        assert_eq!(rows[0].1, 4 * 129);
        assert_eq!(rows[2].1, 4 * 129 * 8);
    }
}
