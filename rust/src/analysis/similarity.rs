//! Router-activation similarity analysis (Fig. 8).
//!
//! Given per-instance router score vectors on a shared held-out evaluation
//! set, builds the 10x10 pairwise cosine matrix and the per-image patch
//! selection heatmaps the paper plots.

use anyhow::{bail, Result};

/// Pairwise cosine-similarity matrix of `n` activation vectors.
pub fn cosine_matrix(vecs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
    let n = vecs.len();
    if n == 0 {
        return Ok(vec![]);
    }
    let d = vecs[0].len();
    if vecs.iter().any(|v| v.len() != d) {
        bail!("cosine_matrix: inconsistent vector lengths");
    }
    let norms: Vec<f64> = vecs
        .iter()
        .map(|v| v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt())
        .collect();
    let mut out = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for j in i..n {
            let dot: f64 = vecs[i]
                .iter()
                .zip(&vecs[j])
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let denom = norms[i] * norms[j];
            let c = if denom > 0.0 { dot / denom } else { 0.0 };
            out[i][j] = c;
            out[j][i] = c;
        }
    }
    Ok(out)
}

/// ASCII heatmap of a patch-selection mask (row-major grid of side `side`),
/// used for the Fig. 8 right-panel rendering in reports.
pub fn ascii_heatmap(mask: &[f32], side: usize) -> Result<String> {
    if mask.len() != side * side {
        bail!("ascii_heatmap: {} values for {}x{} grid", mask.len(), side, side);
    }
    const SHADES: [char; 5] = [' ', '.', ':', 'o', '#'];
    let mut out = String::new();
    for y in 0..side {
        for x in 0..side {
            let v = mask[y * side + x].clamp(0.0, 1.0);
            let idx = ((v * (SHADES.len() - 1) as f32).round()) as usize;
            out.push(SHADES[idx.min(SHADES.len() - 1)]);
            out.push(SHADES[idx.min(SHADES.len() - 1)]);
        }
        out.push('\n');
    }
    Ok(out)
}

/// Selection-overlap (IoU) between two boolean patch masks — the scalar we
/// report alongside the Fig. 8 heatmaps.
pub fn mask_iou(a: &[f32], b: &[f32]) -> Result<f64> {
    if a.len() != b.len() {
        bail!("mask_iou: length mismatch");
    }
    let mut inter = 0usize;
    let mut union = 0usize;
    for (&x, &y) in a.iter().zip(b) {
        let (sx, sy) = (x > 0.5, y > 0.5);
        if sx && sy {
            inter += 1;
        }
        if sx || sy {
            union += 1;
        }
    }
    Ok(if union == 0 { 1.0 } else { inter as f64 / union as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_symmetric_unit_diagonal() {
        let vecs = vec![vec![1.0, 0.0, 2.0], vec![0.5, 1.0, 0.0],
                        vec![1.0, 0.1, 1.9]];
        let m = cosine_matrix(&vecs).unwrap();
        for i in 0..3 {
            assert!((m[i][i] - 1.0).abs() < 1e-9);
            for j in 0..3 {
                assert!((m[i][j] - m[j][i]).abs() < 1e-12);
            }
        }
        // vec 0 and vec 2 are nearly parallel
        assert!(m[0][2] > m[0][1]);
    }

    #[test]
    fn rejects_ragged() {
        assert!(cosine_matrix(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn heatmap_shape() {
        let mask = vec![0.0, 1.0, 0.5, 0.0];
        let h = ascii_heatmap(&mask, 2).unwrap();
        assert_eq!(h.lines().count(), 2);
        assert!(h.contains('#'));
        assert!(ascii_heatmap(&mask, 3).is_err());
    }

    #[test]
    fn iou_cases() {
        let a = vec![1.0, 1.0, 0.0, 0.0];
        let b = vec![1.0, 0.0, 1.0, 0.0];
        assert!((mask_iou(&a, &b).unwrap() - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(mask_iou(&a, &a).unwrap(), 1.0);
        assert_eq!(mask_iou(&[0.0, 0.0], &[0.0, 0.0]).unwrap(), 1.0);
    }
}
