//! Analysis substrates: FLOPs/active-parameter accounting (Table 1 and the
//! capacity→compute mapping of every scaling figure) and router-activation
//! similarity (Fig. 8).

pub mod flops;
pub mod similarity;
