//! Executable cache + typed execution over the PJRT CPU client.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`.  Entries compile lazily on first use and
//! stay cached for the process lifetime (compilation of the big distill
//! steps takes seconds; the request path must never pay it twice).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{EntrySpec, Manifest};

/// Typed host-side argument for an entry call.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

/// Decomposed tuple outputs of one execution.
pub struct Outputs {
    pub literals: Vec<Literal>,
}

impl Outputs {
    pub fn f32(&self, i: usize) -> Result<Vec<f32>> {
        self.literals
            .get(i)
            .ok_or_else(|| anyhow!("no output {i}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("output {i} as f32: {e}"))
    }

    pub fn scalar_f32(&self, i: usize) -> Result<f32> {
        let v = self.f32(i)?;
        if v.len() != 1 {
            bail!("output {i} has {} elems, wanted scalar", v.len());
        }
        Ok(v[0])
    }

    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }
}

/// Cumulative execution statistics (perf accounting).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// One artifact set (config) loaded onto a PJRT client.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Load the manifest for `config` under `artifacts_dir` and create the
    /// CPU PJRT client.  Executables compile lazily via `exec`/`warmup`.
    pub fn load(artifacts_dir: &str, config: &str) -> Result<Runtime> {
        let dir = PathBuf::from(artifacts_dir).join(config);
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Compile (or fetch from cache) one entry's executable.
    fn ensure_compiled(&self, entry: &str) -> Result<()> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains_key(entry) {
                return Ok(());
            }
        }
        let spec = self.manifest.entry(entry)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {entry}: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            stats.compiles += 1;
            stats.compile_secs += dt;
        }
        self.cache.lock().unwrap().insert(entry.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of entries (so timing loops exclude compilation).
    pub fn warmup(&self, entries: &[&str]) -> Result<()> {
        for e in entries {
            self.ensure_compiled(e)?;
        }
        Ok(())
    }

    fn build_literal(spec_shape: &[usize], dtype: &str, arg: &Arg)
                     -> Result<Literal> {
        let dims: Vec<i64> = spec_shape.iter().map(|&d| d as i64).collect();
        let numel: usize = spec_shape.iter().product::<usize>().max(1);
        match (dtype, arg) {
            ("float32", Arg::F32(data)) => {
                if data.len() != numel {
                    bail!("arg wants {numel} f32, got {}", data.len());
                }
                let lit = Literal::vec1(data);
                if spec_shape.is_empty() {
                    Ok(lit.reshape(&[])?)
                } else {
                    Ok(lit.reshape(&dims)?)
                }
            }
            ("int32", Arg::I32(data)) => {
                if data.len() != numel {
                    bail!("arg wants {numel} i32, got {}", data.len());
                }
                let lit = Literal::vec1(data);
                if spec_shape.is_empty() {
                    Ok(lit.reshape(&[])?)
                } else {
                    Ok(lit.reshape(&dims)?)
                }
            }
            ("float32", Arg::ScalarF32(x)) => {
                if numel != 1 {
                    bail!("scalar arg for non-scalar spec {spec_shape:?}");
                }
                if spec_shape.is_empty() {
                    Ok(Literal::scalar(*x))
                } else {
                    Ok(Literal::vec1(&[*x]).reshape(&dims)?)
                }
            }
            ("int32", Arg::ScalarI32(x)) => {
                if numel != 1 {
                    bail!("scalar arg for non-scalar spec {spec_shape:?}");
                }
                if spec_shape.is_empty() {
                    Ok(Literal::scalar(*x))
                } else {
                    Ok(Literal::vec1(&[*x]).reshape(&dims)?)
                }
            }
            (dt, _) => bail!("arg/dtype mismatch for {dt}"),
        }
    }

    /// Build + validate the literal for one argument of an entry.
    /// Hot paths can prepare static arguments (the big frozen param
    /// vectors) once and reuse them across calls via [`exec_prepared`].
    pub fn prepare_arg(&self, entry: &str, index: usize, arg: &Arg)
                       -> Result<Literal> {
        let spec = self.manifest.entry(entry)?;
        let s = spec.args.get(index).ok_or_else(|| {
            anyhow!("{entry}: no arg {index} (has {})", spec.args.len())
        })?;
        Self::build_literal(&s.shape, &s.dtype, arg)
            .with_context(|| format!("{entry}: arg {:?}", s.name))
    }

    /// Execute an entry with typed args; returns decomposed tuple outputs.
    pub fn exec(&self, entry: &str, args: &[Arg]) -> Result<Outputs> {
        let spec = self.manifest.entry(entry)?;
        if args.len() != spec.args.len() {
            bail!("{entry}: got {} args, manifest wants {} ({:?})",
                  args.len(), spec.args.len(),
                  spec.args.iter().map(|a| &a.name).collect::<Vec<_>>());
        }
        let mut literals = Vec::with_capacity(args.len());
        for (a, s) in args.iter().zip(&spec.args) {
            literals.push(Self::build_literal(&s.shape, &s.dtype, a)
                .with_context(|| format!("{entry}: arg {:?}", s.name))?);
        }
        let refs: Vec<&Literal> = literals.iter().collect();
        self.exec_prepared(entry, &refs)
    }

    /// Execute with pre-built literals (mix cached static args with fresh
    /// per-request ones).  The serving engine uses this to avoid re-copying
    /// the multi-MB frozen parameter vector on every batch.
    pub fn exec_prepared(&self, entry: &str, literals: &[&Literal])
                         -> Result<Outputs> {
        self.ensure_compiled(entry)?;
        let spec: &EntrySpec = self.manifest.entry(entry)?;
        if literals.len() != spec.args.len() {
            bail!("{entry}: got {} literals, manifest wants {}",
                  literals.len(), spec.args.len());
        }
        let n_outputs = spec.outputs.len();
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(entry).expect("ensured above");
        let t0 = Instant::now();
        let result = exe
            .execute::<&Literal>(literals)
            .map_err(|e| anyhow!("execute {entry}: {e}"))?;
        let root = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("{entry}: no output buffer"))?;
        let lit = root
            .to_literal_sync()
            .map_err(|e| anyhow!("{entry}: to_literal: {e}"))?;
        let outs = lit
            .to_tuple()
            .map_err(|e| anyhow!("{entry}: untuple: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            stats.executions += 1;
            stats.execute_secs += dt;
        }
        if outs.len() != n_outputs {
            bail!("{entry}: {} outputs, manifest wants {}",
                  outs.len(), n_outputs);
        }
        Ok(Outputs { literals: outs })
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.manifest.entries.contains_key(entry)
    }
}
