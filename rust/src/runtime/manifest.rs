//! Manifest parsing: the JSON contract emitted by `compile/aot.py`.
//!
//! Carries (a) the model config, (b) the flat-parameter layout tables for
//! teacher and router vectors, and (c) per-entry argument/output specs the
//! runtime validates calls against.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{self, Value};

#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct OutSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<OutSpec>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// A parameter layout table (ordered, contiguous, gap-free).
#[derive(Debug, Clone, Default)]
pub struct ParamTable {
    pub entries: Vec<ParamEntry>,
}

impl ParamTable {
    pub fn total(&self) -> usize {
        self.entries
            .last()
            .map(|e| e.offset + e.size)
            .unwrap_or(0)
    }

    pub fn find(&self, name: &str) -> Option<&ParamEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Slice one named tensor out of a flat buffer.
    pub fn slice<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let e = self
            .find(name)
            .ok_or_else(|| anyhow!("no param named {name:?}"))?;
        if flat.len() < e.offset + e.size {
            bail!("flat buffer too short for {name:?}");
        }
        Ok(&flat[e.offset..e.offset + e.size])
    }

    fn from_json(v: &Value) -> Result<ParamTable> {
        let mut entries = Vec::new();
        let mut expect_off = 0usize;
        for item in v.as_arr()? {
            let e = ParamEntry {
                name: item.req("name")?.as_str()?.to_string(),
                shape: item.req("shape")?.as_usize_vec()?,
                offset: item.req("offset")?.as_usize()?,
                size: item.req("size")?.as_usize()?,
            };
            if e.offset != expect_off {
                bail!("param table gap at {:?}", e.name);
            }
            expect_off += e.size;
            entries.push(e);
        }
        Ok(ParamTable { entries })
    }
}

/// Parsed manifest for one artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: Value,
    pub entries: BTreeMap<String, EntrySpec>,
    pub teacher_params: ParamTable,
    pub router_params: BTreeMap<String, ParamTable>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("read {path:?} — run `make artifacts` first")
        })?;
        let root = json::parse(&text)?;

        let mut entries = BTreeMap::new();
        for (name, e) in root.req("entries")?.as_obj()? {
            let args = e
                .req("args")?
                .as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.req("name")?.as_str()?.to_string(),
                        shape: a.req("shape")?.as_usize_vec()?,
                        dtype: a.req("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(|o| {
                    Ok(OutSpec {
                        shape: o.req("shape")?.as_usize_vec()?,
                        dtype: o.req("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: e.req("file")?.as_str()?.to_string(),
                    args,
                    outputs,
                },
            );
        }

        let teacher_params = ParamTable::from_json(root.req("teacher_params")?)?;
        let mut router_params = BTreeMap::new();
        for (k, v) in root.req("router_params")?.as_obj()? {
            router_params.insert(k.clone(), ParamTable::from_json(v)?);
        }

        Ok(Manifest {
            dir,
            config: root.req("config")?.clone(),
            entries,
            teacher_params,
            router_params,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!("no entry {name:?} in manifest (have: {:?})",
                    self.entries.keys().collect::<Vec<_>>())
        })
    }

    // -- typed config accessors --------------------------------------------

    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config.req(key)?.as_usize()
    }

    pub fn cfg_str(&self, key: &str) -> Result<&str> {
        self.config.req(key)?.as_str()
    }

    pub fn name(&self) -> &str {
        self.cfg_str("name").unwrap_or("?")
    }

    pub fn kind(&self) -> &str {
        self.cfg_str("kind").unwrap_or("?")
    }

    pub fn batch(&self) -> usize {
        self.cfg_usize("batch").unwrap_or(1)
    }

    pub fn seq_len(&self) -> usize {
        self.cfg_usize("seq_len").unwrap_or(0)
    }

    pub fn n_layers(&self) -> usize {
        self.cfg_usize("n_layers").unwrap_or(0)
    }

    pub fn n_heads(&self) -> usize {
        self.cfg_usize("n_heads").unwrap_or(0)
    }

    pub fn vocab(&self) -> usize {
        self.cfg_usize("vocab").unwrap_or(0)
    }

    pub fn dims(&self) -> Result<crate::analysis::flops::ModelDims> {
        Ok(crate::analysis::flops::ModelDims {
            d_model: self.cfg_usize("d_model")?,
            n_layers: self.cfg_usize("n_layers")?,
            n_heads: self.cfg_usize("n_heads")?,
            d_ff: self.cfg_usize("d_ff")?,
            seq_len: self.cfg_usize("seq_len")?,
            vocab: self.cfg_usize("vocab").unwrap_or(0),
            n_experts: self.cfg_usize("n_experts").unwrap_or(1),
        })
    }

    pub fn router_table(&self, key: &str) -> Result<&ParamTable> {
        self.router_params.get(key).ok_or_else(|| {
            anyhow!("no router table {key:?} (have: {:?})",
                    self.router_params.keys().collect::<Vec<_>>())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
          "fingerprint": "x",
          "config": {"name": "m", "kind": "lm", "batch": 2, "seq_len": 8,
                     "d_model": 16, "n_layers": 2, "n_heads": 2, "d_ff": 32,
                     "vocab": 256, "n_experts": 4},
          "entries": {
            "init": {"name": "init", "file": "init.hlo.txt",
                     "args": [{"name": "seed", "shape": [], "dtype": "int32"}],
                     "outputs": [{"shape": [10], "dtype": "float32"}]}
          },
          "teacher_params": [
            {"name": "a", "shape": [2, 3], "offset": 0, "size": 6},
            {"name": "b", "shape": [4], "offset": 6, "size": 4}
          ],
          "router_params": {"0": [
            {"name": "r", "shape": [5], "offset": 0, "size": 5}
          ]}
        }"#
        .to_string()
    }

    fn write_fake(dirname: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(dirname);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        dir
    }

    #[test]
    fn parses_and_validates() {
        let dir = write_fake("ef_manifest_ok");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.name(), "m");
        assert_eq!(m.teacher_params.total(), 10);
        assert_eq!(m.router_table("0").unwrap().total(), 5);
        let e = m.entry("init").unwrap();
        assert_eq!(e.args[0].dtype, "int32");
        assert_eq!(e.args[0].numel(), 1);
        assert!(m.entry("nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slice_named_param() {
        let dir = write_fake("ef_manifest_slice");
        let m = Manifest::load(&dir).unwrap();
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(m.teacher_params.slice(&flat, "b").unwrap(),
                   &[6.0, 7.0, 8.0, 9.0]);
        assert!(m.teacher_params.slice(&flat[..5], "b").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_gapped_table() {
        let dir = std::env::temp_dir().join("ef_manifest_gap");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = fake_manifest_json().replace(
            r#""offset": 6, "size": 4"#, r#""offset": 7, "size": 4"#);
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dims_accessor() {
        let dir = write_fake("ef_manifest_dims");
        let m = Manifest::load(&dir).unwrap();
        let d = m.dims().unwrap();
        assert_eq!(d.d_model, 16);
        assert_eq!(d.n_experts, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
