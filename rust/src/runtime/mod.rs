//! PJRT runtime: loads the AOT artifacts (`artifacts/<config>/*.hlo.txt`)
//! produced by `python -m compile.aot` and executes them on the XLA CPU
//! client.  Python never runs here — this module plus the manifest is the
//! entire contract between the layers.

pub mod manifest;
pub mod client;

pub use client::{Outputs, Runtime};
pub use manifest::{ArgSpec, EntrySpec, Manifest, ParamEntry};
