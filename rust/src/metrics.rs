//! Metrics substrate: run-scoped loggers (JSONL + CSV), summary statistics
//! and the bootstrap confidence intervals used by the Fig. 9 evaluation
//! (95% CI over 100 resamples, matching the paper's protocol).

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Value;
use crate::rng::Rng;

/// Append-only JSONL metrics log (one object per step/event).
pub struct JsonlLogger {
    path: PathBuf,
    file: fs::File,
}

impl JsonlLogger {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<JsonlLogger> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let file = fs::File::create(&path)
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        Ok(JsonlLogger { path: path.as_ref().to_path_buf(), file })
    }

    pub fn log(&mut self, fields: Vec<(String, Value)>) -> Result<()> {
        let line = crate::json::to_string(&Value::Obj(fields));
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Write a string to a file, creating parents.
pub fn write_file<P: AsRef<Path>>(path: P, content: &str) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(&path, content)
        .with_context(|| format!("write {:?}", path.as_ref()))
}

// ---------------------------------------------------------------------------
// summary statistics
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Bootstrap mean CI: `resamples` resamples with replacement, returning
/// (mean, lo, hi) at the given two-sided confidence level.
pub fn bootstrap_ci(xs: &[f64], resamples: usize, conf: f64, seed: u64)
                    -> (f64, f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut rng = Rng::new(seed);
    let mut means: Vec<f64> = (0..resamples)
        .map(|_| {
            let s: f64 = (0..xs.len())
                .map(|_| xs[rng.below(xs.len())])
                .sum();
            s / xs.len() as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - conf) / 2.0;
    let lo_i = ((resamples as f64) * alpha) as usize;
    let hi_i = (((resamples as f64) * (1.0 - alpha)) as usize)
        .min(resamples - 1);
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    (mean, means[lo_i], means[hi_i])
}

/// Exponential moving average (loss-curve smoothing in reports).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_contains_mean() {
        let xs: Vec<f64> = (0..200).map(|i| (i % 7) as f64).collect();
        let (mean, lo, hi) = bootstrap_ci(&xs, 100, 0.95, 42);
        assert!(lo <= mean && mean <= hi);
        assert!(hi - lo < 1.0, "CI too wide: {lo}..{hi}");
    }

    #[test]
    fn bootstrap_deterministic() {
        let xs = [1.0, 5.0, 3.0, 2.0];
        assert_eq!(bootstrap_ci(&xs, 50, 0.95, 7),
                   bootstrap_ci(&xs, 50, 0.95, 7));
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0]);
    }

    #[test]
    fn jsonl_logger_roundtrip() {
        let dir = std::env::temp_dir().join("elastiformer_test_metrics");
        let path = dir.join("log.jsonl");
        {
            let mut l = JsonlLogger::create(&path).unwrap();
            l.log(vec![("step".into(), Value::from(1usize)),
                       ("loss".into(), Value::from(0.5))]).unwrap();
            l.log(vec![("step".into(), Value::from(2usize))]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = crate::json::parse(lines[0]).unwrap();
        assert_eq!(v.get("loss").unwrap().as_f64().unwrap(), 0.5);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
