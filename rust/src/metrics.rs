//! Metrics substrate: run-scoped loggers (JSONL + CSV), summary statistics
//! the bootstrap confidence intervals used by the Fig. 9 evaluation
//! (95% CI over 100 resamples, matching the paper's protocol), and the
//! shared log2-bucket latency histogram ([`Log2Hist`]) the serving
//! subsystem uses for bounded-memory percentiles (live snapshots and
//! the shutdown report).

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::json::Value;
use crate::rng::Rng;

/// Append-only JSONL metrics log (one object per step/event).
pub struct JsonlLogger {
    path: PathBuf,
    file: fs::File,
}

impl JsonlLogger {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<JsonlLogger> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let file = fs::File::create(&path)
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        Ok(JsonlLogger { path: path.as_ref().to_path_buf(), file })
    }

    pub fn log(&mut self, fields: Vec<(String, Value)>) -> Result<()> {
        let line = crate::json::to_string(&Value::Obj(fields));
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Write a string to a file, creating parents.
pub fn write_file<P: AsRef<Path>>(path: P, content: &str) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(&path, content)
        .with_context(|| format!("write {:?}", path.as_ref()))
}

// ---------------------------------------------------------------------------
// summary statistics
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Bootstrap mean CI: `resamples` resamples with replacement, returning
/// (mean, lo, hi) at the given two-sided confidence level.
pub fn bootstrap_ci(xs: &[f64], resamples: usize, conf: f64, seed: u64)
                    -> (f64, f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut rng = Rng::new(seed);
    let mut means: Vec<f64> = (0..resamples)
        .map(|_| {
            let s: f64 = (0..xs.len())
                .map(|_| xs[rng.below(xs.len())])
                .sum();
            s / xs.len() as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - conf) / 2.0;
    let lo_i = ((resamples as f64) * alpha) as usize;
    let hi_i = (((resamples as f64) * (1.0 - alpha)) as usize)
        .min(resamples - 1);
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    (mean, means[lo_i], means[hi_i])
}

// ---------------------------------------------------------------------------
// log2-bucket latency histogram
// ---------------------------------------------------------------------------

/// Number of buckets in a [`Log2Hist`]: 4 unit buckets for 0..4µs plus
/// 4 linear sub-buckets per power-of-two octave up to `u64::MAX` µs.
pub const LOG2_HIST_BUCKETS: usize = 252;

/// Fixed-size log2-bucket histogram over microsecond samples.
///
/// Each power-of-two octave `[2^k, 2^(k+1))` is split into 4 linear
/// sub-buckets, so a reported quantile (bucket midpoint) is always
/// within half a bucket width — at most ~12.5% relative error — of the
/// exact sample, while the whole structure is 252 fixed counters no
/// matter how many samples land in it.  Observation is a single
/// `Relaxed` atomic increment, so workers can record latencies on the
/// hot path and a live snapshot can read the buckets mid-run without
/// any lock; the counters are independent monotone event counts, so a
/// torn read across buckets can only undercount the still-arriving
/// tail, never corrupt the histogram.
#[derive(Debug)]
pub struct Log2Hist {
    buckets: Vec<AtomicU64>,
}

impl Default for Log2Hist {
    fn default() -> Self {
        Log2Hist::new()
    }
}

impl Clone for Log2Hist {
    fn clone(&self) -> Self {
        let h = Log2Hist::new();
        for (i, b) in self.buckets.iter().enumerate() {
            h.buckets[i].store(b.load(Ordering::Relaxed),
                               Ordering::Relaxed);
        }
        h
    }
}

impl Log2Hist {
    pub fn new() -> Log2Hist {
        Log2Hist {
            buckets: (0..LOG2_HIST_BUCKETS)
                .map(|_| AtomicU64::new(0))
                .collect(),
        }
    }

    /// Build a histogram from a slice of millisecond samples (the
    /// report path: completions already hold latencies in ms).
    pub fn from_ms(values: &[f64]) -> Log2Hist {
        let h = Log2Hist::new();
        for &v in values {
            h.observe_ms(v);
        }
        h
    }

    /// Bucket index for a microsecond sample.
    fn index(us: u64) -> usize {
        if us < 4 {
            return us as usize;
        }
        let octave = 63 - us.leading_zeros() as usize; // >= 2
        let sub = ((us >> (octave - 2)) & 3) as usize;
        4 + (octave - 2) * 4 + sub
    }

    /// `[lo, hi)` bounds in µs of bucket `i`.
    pub fn bucket_bounds_us(i: usize) -> (u64, u64) {
        if i < 4 {
            return (i as u64, i as u64 + 1);
        }
        let octave = 2 + (i - 4) / 4;
        let sub = ((i - 4) % 4) as u64;
        let width = 1u64 << (octave - 2);
        let lo = (1u64 << octave) + sub * width;
        (lo, lo.saturating_add(width))
    }

    /// `[lo, hi)` bounds in ms of the bucket a millisecond sample
    /// falls into — what "within one bucket width" means in tests.
    pub fn bucket_bounds_ms(ms: f64) -> (f64, f64) {
        let us = (ms.max(0.0) * 1000.0).round() as u64;
        let (lo, hi) = Log2Hist::bucket_bounds_us(Log2Hist::index(us));
        (lo as f64 / 1000.0, hi as f64 / 1000.0)
    }

    pub fn observe_us(&self, us: u64) {
        // Relaxed: independent monotone counter, no ordering needed
        self.buckets[Log2Hist::index(us)]
            .fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_ms(&self, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        self.observe_us((ms * 1000.0).round() as u64);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Nearest-rank quantile over the buckets, reported as the target
    /// bucket's midpoint in ms.  `0.0` on an empty histogram.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64)
            .clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let (lo, hi) = Log2Hist::bucket_bounds_us(i);
                return (lo as f64 + hi as f64) / 2.0 / 1000.0;
            }
        }
        unreachable!("rank {rank} <= total {total} must land in a bucket");
    }

    /// Nonzero buckets as `(lo_us, hi_us, count)` — snapshot material.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                if c == 0 {
                    return None;
                }
                let (lo, hi) = Log2Hist::bucket_bounds_us(i);
                Some((lo, hi, c))
            })
            .collect()
    }
}

/// Exponential moving average (loss-curve smoothing in reports).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_contains_mean() {
        let xs: Vec<f64> = (0..200).map(|i| (i % 7) as f64).collect();
        let (mean, lo, hi) = bootstrap_ci(&xs, 100, 0.95, 42);
        assert!(lo <= mean && mean <= hi);
        assert!(hi - lo < 1.0, "CI too wide: {lo}..{hi}");
    }

    #[test]
    fn bootstrap_deterministic() {
        let xs = [1.0, 5.0, 3.0, 2.0];
        assert_eq!(bootstrap_ci(&xs, 50, 0.95, 7),
                   bootstrap_ci(&xs, 50, 0.95, 7));
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0]);
    }

    #[test]
    fn log2_hist_buckets_partition_the_line() {
        // every µs value maps to exactly one bucket whose bounds
        // contain it, and bucket bounds tile without gaps or overlaps
        let mut prev_hi = 0u64;
        for i in 0..LOG2_HIST_BUCKETS {
            let (lo, hi) = Log2Hist::bucket_bounds_us(i);
            assert_eq!(lo, prev_hi, "gap/overlap at bucket {i}");
            assert!(hi > lo || hi == u64::MAX, "empty bucket {i}");
            prev_hi = hi;
        }
        for us in [0u64, 1, 3, 4, 7, 8, 100, 999, 12_345, u64::MAX / 2] {
            let h = Log2Hist::new();
            h.observe_us(us);
            let nz = h.nonzero_buckets();
            assert_eq!(nz.len(), 1);
            let (lo, hi, c) = nz[0];
            assert_eq!(c, 1);
            assert!(lo <= us && us < hi,
                    "{us} outside its bucket [{lo}, {hi})");
        }
    }

    #[test]
    fn log2_hist_quantile_within_half_a_bucket() {
        let values: Vec<f64> =
            (1..=100).map(|i| i as f64 * 0.37 + 0.05).collect();
        let h = Log2Hist::from_ms(&values);
        assert_eq!(h.count(), 100);
        for &q in &[0.5, 0.9, 0.99] {
            let rank =
                ((q * 100.0f64).ceil() as usize).clamp(1, 100) - 1;
            let exact = values[rank]; // values are already sorted
            let (lo, hi) = Log2Hist::bucket_bounds_ms(exact);
            let est = h.quantile_ms(q);
            assert!(est >= lo - 1e-9 && est <= hi + 1e-9,
                    "q{q}: estimate {est} outside [{lo}, {hi}] \
                     around exact {exact}");
        }
        assert_eq!(Log2Hist::new().quantile_ms(0.5), 0.0);
    }

    #[test]
    fn jsonl_logger_roundtrip() {
        let dir = std::env::temp_dir().join("elastiformer_test_metrics");
        let path = dir.join("log.jsonl");
        {
            let mut l = JsonlLogger::create(&path).unwrap();
            l.log(vec![("step".into(), Value::from(1usize)),
                       ("loss".into(), Value::from(0.5))]).unwrap();
            l.log(vec![("step".into(), Value::from(2usize))]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = crate::json::parse(lines[0]).unwrap();
        assert_eq!(v.get("loss").unwrap().as_f64().unwrap(), 0.5);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
