//! Deterministic RNG substrate (no `rand` crate in the vendored set).
//!
//! SplitMix64 seeding + xoshiro256** core, Box–Muller gaussians, and the
//! sampling helpers the data generators / experiment drivers need.  All
//! experiment randomness flows through explicit seeds so every paper figure
//! regenerates bit-identically.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box–Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-worker / per-experiment rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // multiply-shift; bias < 2^-64, irrelevant here
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn gaussian_f32(&mut self, std: f32) -> f32 {
        (self.gaussian() as f32) * std
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "non-uniform: {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let k = r.below(20) + 1;
            let idx = r.sample_indices(32, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k);
            assert!(idx.iter().all(|&i| i < 32));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
