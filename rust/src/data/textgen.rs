//! Pretraining corpus for the teacher LM: a mixture of the math and code
//! corpora plus simple narrative sentences, so the byte-level teacher
//! learns genuine structure (vocabulary, arithmetic patterns, code syntax)
//! before ElastiFormer distillation begins.

use crate::rng::Rng;

use super::{codegen, mathgen};

const SUBJECTS: &[&str] = &[
    "the cat", "a small bird", "the old robot", "the river", "a tall tree",
    "the quiet town", "the red kite", "a young fox",
];

const VERBS: &[&str] = &[
    "watched", "followed", "found", "carried", "remembered", "crossed",
    "painted", "counted",
];

const OBJECTS: &[&str] = &[
    "the bright moon", "three silver keys", "an open door", "the long road",
    "a box of letters", "the winter rain", "seven lanterns", "the last map",
];

fn sentence(rng: &mut Rng) -> String {
    format!(
        "{} {} {}.",
        rng.choose(SUBJECTS),
        rng.choose(VERBS),
        rng.choose(OBJECTS)
    )
}

/// One pretraining document (narrative / math / code, 50/30/20 mix).
pub fn gen_document(rng: &mut Rng) -> String {
    match rng.below(10) {
        0..=4 => {
            let n = rng.range(2, 5);
            (0..n).map(|_| sentence(rng)).collect::<Vec<_>>().join(" ")
        }
        5..=7 => mathgen::gen_problem(rng).full_text(),
        _ => codegen::gen_snippet(rng).full_text(),
    }
}

pub fn dataset(n: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen_document(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_varied() {
        let a = dataset(30, 5);
        assert_eq!(a, dataset(30, 5));
        let uniq: std::collections::HashSet<_> = a.iter().collect();
        assert!(uniq.len() > 25);
    }

    #[test]
    fn mixture_contains_all_domains() {
        let docs = dataset(200, 6);
        let joined = docs.join("\n");
        assert!(joined.contains("The answer is"));
        assert!(joined.contains("def "));
        assert!(joined.contains("."));
    }

    #[test]
    fn nonempty_docs() {
        assert!(dataset(50, 7).iter().all(|d| d.len() > 10));
    }
}
