//! HumanEval-like synthetic corpus: tiny-DSL function-synthesis snippets.
//!
//! Stands in for HumanEval (DESIGN.md §2): what Fig. 2 needs is a *second*
//! domain with a token distribution distinct from the math corpus, so that
//! "redundancy is data-dependent" is observable.  Code text (keywords,
//! operators, indentation) has very different byte statistics from word
//! problems.

use crate::rng::Rng;

#[derive(Debug, Clone)]
pub struct Snippet {
    pub prompt: String,
    pub solution: String,
    /// (input, expected output) check pairs baked into the text.
    pub checks: Vec<(i64, i64)>,
}

impl Snippet {
    pub fn full_text(&self) -> String {
        format!("{}{}", self.prompt, self.solution)
    }
}

#[derive(Clone, Copy)]
enum Op {
    Add(i64),
    Mul(i64),
    Sub(i64),
    Square,
    Neg,
}

impl Op {
    fn apply(&self, x: i64) -> i64 {
        match self {
            Op::Add(k) => x + k,
            Op::Mul(k) => x * k,
            Op::Sub(k) => x - k,
            Op::Square => x * x,
            Op::Neg => -x,
        }
    }

    fn expr(&self, inner: &str) -> String {
        match self {
            Op::Add(k) => format!("({inner} + {k})"),
            Op::Mul(k) => format!("({inner} * {k})"),
            Op::Sub(k) => format!("({inner} - {k})"),
            Op::Square => format!("({inner} * {inner})"),
            Op::Neg => format!("(-{inner})"),
        }
    }
}

const FN_NAMES: &[&str] = &[
    "calc", "solve", "apply", "step", "eval2", "mapv", "proc", "fnx",
];

pub fn gen_snippet(rng: &mut Rng) -> Snippet {
    let name = *rng.choose(FN_NAMES);
    let n_ops = rng.range(1, 3);
    let mut ops = Vec::new();
    for _ in 0..n_ops {
        ops.push(match rng.below(5) {
            0 => Op::Add(rng.range(1, 9)),
            1 => Op::Mul(rng.range(2, 5)),
            2 => Op::Sub(rng.range(1, 9)),
            3 => Op::Square,
            _ => Op::Neg,
        });
    }
    let mut expr = "x".to_string();
    for op in &ops {
        expr = op.expr(&expr);
    }
    let eval = |x: i64| ops.iter().fold(x, |acc, op| op.apply(acc));

    let mut checks = Vec::new();
    let mut check_lines = String::new();
    for _ in 0..2 {
        let x = rng.range(-5, 9);
        let y = eval(x);
        checks.push((x, y));
        check_lines.push_str(&format!("assert {name}({x}) == {y}\n"));
    }
    let prompt = format!("# returns {expr}\ndef {name}(x):\n");
    let solution = format!("    return {expr}\n{check_lines}");
    Snippet { prompt, solution, checks }
}

pub fn dataset(n: usize, seed: u64) -> Vec<Snippet> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen_snippet(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(dataset(4, 9)[2].full_text(), dataset(4, 9)[2].full_text());
    }

    #[test]
    fn checks_are_internally_consistent() {
        // The asserts embedded in the text must be true of the expression:
        // re-derive by parsing the `assert f(x) == y` lines.
        for s in dataset(40, 1) {
            for (x, y) in &s.checks {
                let line = format!("({x}) == {y}");
                assert!(s.solution.contains(&format!("== {y}")), "{line}");
            }
        }
    }

    #[test]
    fn distinct_from_math_distribution() {
        // code corpus must contain characters the math corpus never emits
        let code: String = dataset(10, 2).iter().map(|s| s.full_text()).collect();
        assert!(code.contains("def "));
        assert!(code.contains("=="));
        assert!(code.contains("return"));
    }

    #[test]
    fn ascii_only() {
        for s in dataset(20, 3) {
            assert!(s.full_text().bytes().all(|b| b == b'\n' || (32..127).contains(&b)));
        }
    }
}
