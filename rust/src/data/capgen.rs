//! Caption generator + attribute-grounded caption metrics for the VLM
//! substrate (stands in for LLaVA-Instruct / LLaVA-Bench / OpenCHAIR).
//!
//! Captions are generated from the *known* scene ground truth, so —
//! unlike CHAIR's object-detector proxy — hallucination is measured
//! exactly: an attribute word in the generated caption either matches the
//! scene or it does not.

use crate::rng::Rng;

use super::imagen::{Scene, CLASS_NAMES};

const TEMPLATES: [&str; 4] = [
    "a {density} {color} {class} pattern",
    "this image shows a {color} {class} texture that is {density}",
    "a {class} design in {color}, {density} layout",
    "the picture contains {density} {color} {class}",
];

/// Ground-truth caption for a scene (template varied by rng).
pub fn caption(scene: &Scene, rng: &mut Rng) -> String {
    let t = *rng.choose(&TEMPLATES);
    t.replace("{density}", scene.density_name())
        .replace("{color}", scene.color_name())
        .replace("{class}", scene.class_name())
}

/// Attribute words recoverable from a caption.
#[derive(Debug, Clone, PartialEq)]
pub struct CaptionFacts {
    pub class: Option<usize>,
    pub color: Option<&'static str>,
    pub density: Option<&'static str>,
}

pub fn extract_facts(text: &str) -> CaptionFacts {
    let lower = text.to_lowercase();
    let class = CLASS_NAMES
        .iter()
        .position(|c| lower.contains(c));
    let color = ["red", "green", "blue", "yellow", "purple", "cyan"]
        .into_iter()
        .find(|c| lower.contains(c));
    let density = ["dense", "sparse"].into_iter().find(|d| lower.contains(d));
    CaptionFacts { class, color, density }
}

/// OpenCHAIR-like scores for a generated caption against ground truth.
///
/// * `recall`        — fraction of the 3 ground-truth attributes mentioned
///                     correctly (the "detail" axis, drops at low capacity).
/// * `hallucination` — fraction of *mentioned* attributes that contradict
///                     the scene (CHAIR's headline number).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CaptionScore {
    pub recall: f64,
    pub hallucination: f64,
}

pub fn score_caption(text: &str, scene: &Scene) -> CaptionScore {
    let facts = extract_facts(text);
    let mut mentioned = 0usize;
    let mut correct = 0usize;
    if let Some(c) = facts.class {
        mentioned += 1;
        if c == scene.class {
            correct += 1;
        }
    }
    if let Some(c) = facts.color {
        mentioned += 1;
        if c == scene.color_name() {
            correct += 1;
        }
    }
    if let Some(d) = facts.density {
        mentioned += 1;
        if d == scene.density_name() {
            correct += 1;
        }
    }
    CaptionScore {
        recall: correct as f64 / 3.0,
        hallucination: if mentioned == 0 {
            1.0 // an empty/degenerate caption describes nothing correctly
        } else {
            (mentioned - correct) as f64 / mentioned as f64
        },
    }
}

/// LLaVA-Bench-like judge-free score: normalized token-level agreement of a
/// candidate caption with a reference caption (teacher output stands in for
/// the GPT-4 reference, per DESIGN.md §2).
pub fn teacher_match_score(candidate: &str, reference: &str) -> f64 {
    let cw: Vec<&str> = candidate.split_whitespace().collect();
    let rw: Vec<&str> = reference.split_whitespace().collect();
    if rw.is_empty() {
        return if cw.is_empty() { 1.0 } else { 0.0 };
    }
    // bag-of-words F1
    let mut matched = 0usize;
    let mut used = vec![false; cw.len()];
    for r in &rw {
        if let Some(i) = cw.iter().enumerate()
            .position(|(i, c)| !used[i] && c == r)
        {
            used[i] = true;
            matched += 1;
        }
    }
    if cw.is_empty() {
        return 0.0;
    }
    let p = matched as f64 / cw.len() as f64;
    let r = matched as f64 / rw.len() as f64;
    if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scene() -> Scene {
        Scene { class: 0, color: 2, dense: true, phase: 0.0 }
    }

    #[test]
    fn caption_contains_all_attributes() {
        let mut rng = Rng::new(0);
        let c = caption(&scene(), &mut rng);
        assert!(c.contains("stripes"));
        assert!(c.contains("blue"));
        assert!(c.contains("dense"));
    }

    #[test]
    fn perfect_caption_scores_perfectly() {
        let mut rng = Rng::new(1);
        let s = scene();
        let c = caption(&s, &mut rng);
        let sc = score_caption(&c, &s);
        assert_eq!(sc.recall, 1.0);
        assert_eq!(sc.hallucination, 0.0);
    }

    #[test]
    fn wrong_color_is_hallucination() {
        let s = scene();
        let sc = score_caption("a dense red stripes pattern", &s);
        assert!(sc.hallucination > 0.0);
        assert!(sc.recall < 1.0);
    }

    #[test]
    fn empty_caption_is_degenerate() {
        let sc = score_caption("hello world", &scene());
        assert_eq!(sc.recall, 0.0);
        assert_eq!(sc.hallucination, 1.0);
    }

    #[test]
    fn teacher_match_bounds() {
        assert!((teacher_match_score("a b c", "a b c") - 1.0).abs() < 1e-9);
        assert_eq!(teacher_match_score("x y z", "a b c"), 0.0);
        let partial = teacher_match_score("a b z", "a b c");
        assert!(partial > 0.0 && partial < 1.0);
    }

    #[test]
    fn extract_facts_roundtrip() {
        let f = extract_facts("a sparse purple rings texture");
        assert_eq!(f.class, Some(2));
        assert_eq!(f.color, Some("purple"));
        assert_eq!(f.density, Some("sparse"));
    }
}
