//! Byte-level tokenizer shared (by construction) with the JAX side.
//!
//! Vocabulary = 256: raw bytes, with the 0/1/2 control bytes repurposed as
//! PAD/BOS/EOS (they never occur in the synthetic corpora, which are
//! printable ASCII).  Identical logic needs no cross-language code: the
//! Python side never tokenizes — Rust feeds token ids straight into the
//! AOT executables.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const VOCAB: usize = 256;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Tokenizer {
        Tokenizer
    }

    /// Encode text to token ids (no specials added).  Control bytes < 3 are
    /// mapped to spaces to keep the PAD/BOS/EOS ids unambiguous.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes()
            .map(|b| if b < 3 { b' ' as i32 } else { b as i32 })
            .collect()
    }

    /// BOS + text + EOS, truncated/padded to `len`.
    pub fn encode_padded(&self, text: &str, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        out.push(BOS);
        out.extend(self.encode(text));
        out.truncate(len.saturating_sub(1));
        out.push(EOS);
        while out.len() < len {
            out.push(PAD);
        }
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&i| i >= 3 && i < VOCAB as i32)
            .map(|&i| i as u8 as char)
            .collect()
    }

    /// Decode stopping at the first EOS/PAD (generation output).
    pub fn decode_until_eos(&self, ids: &[i32]) -> String {
        let end = ids
            .iter()
            .position(|&i| i == EOS || i == PAD)
            .unwrap_or(ids.len());
        self.decode(&ids[..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new();
        let s = "Alice has 3 apples + 4 = 7.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn padded_layout() {
        let t = Tokenizer::new();
        let ids = t.encode_padded("hi", 8);
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[0], BOS);
        assert_eq!(ids[3], EOS);
        assert!(ids[4..].iter().all(|&i| i == PAD));
    }

    #[test]
    fn truncation_keeps_eos() {
        let t = Tokenizer::new();
        let ids = t.encode_padded("abcdefghij", 6);
        assert_eq!(ids.len(), 6);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
    }

    #[test]
    fn control_bytes_sanitized() {
        let t = Tokenizer::new();
        let ids = t.encode("a\u{0}b\u{1}c");
        assert!(ids.iter().all(|&i| i >= 3));
    }

    #[test]
    fn decode_until_eos_stops() {
        let t = Tokenizer::new();
        let mut ids = t.encode("hello");
        ids.push(EOS);
        ids.extend(t.encode("junk"));
        assert_eq!(t.decode_until_eos(&ids), "hello");
    }

    #[test]
    fn roundtrip_random_printable() {
        let t = Tokenizer::new();
        let mut rng = crate::rng::Rng::new(0);
        for _ in 0..50 {
            let s: String = (0..40)
                .map(|_| (rng.range(32, 126) as u8) as char)
                .collect();
            assert_eq!(t.decode(&t.encode(&s)), s);
        }
    }
}
