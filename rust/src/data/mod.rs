//! Data substrates: tokenizer + synthetic corpora standing in for the
//! paper's gated datasets (GSM8K, HumanEval, ImageNet-1K, LLaVA-Instruct) —
//! see DESIGN.md §2 for the substitution rationale.

pub mod tokenizer;
pub mod mathgen;
pub mod codegen;
pub mod textgen;
pub mod imagen;
pub mod capgen;
pub mod loader;

pub use loader::{Batcher, TextDataset};
pub use tokenizer::Tokenizer;
