//! Batching + shuffling over tokenized datasets: the host-side input
//! pipeline feeding the AOT executables (i32 token buffers / f32 image
//! buffers, row-major [B, ...]).

use crate::rng::Rng;

use super::tokenizer::Tokenizer;

/// A tokenized text dataset with fixed-length rows.
#[derive(Debug, Clone)]
pub struct TextDataset {
    pub rows: Vec<Vec<i32>>,
    pub seq_len: usize,
}

impl TextDataset {
    pub fn from_texts(texts: &[String], seq_len: usize) -> TextDataset {
        let tok = Tokenizer::new();
        TextDataset {
            rows: texts
                .iter()
                .map(|t| tok.encode_padded(t, seq_len))
                .collect(),
            seq_len,
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Epoch-shuffling batcher producing flat row-major [B, T] buffers.
/// Wraps around dataset boundaries so every batch is full-size (matching
/// the fixed shapes baked into the AOT artifacts).
pub struct Batcher {
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub batch: usize,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(n_rows: usize, batch: usize, seed: u64) -> Batcher {
        assert!(n_rows > 0 && batch > 0);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n_rows).collect();
        rng.shuffle(&mut order);
        Batcher { order, cursor: 0, rng, batch, epoch: 0 }
    }

    /// Indices of the next batch (always exactly `batch` long).
    pub fn next_indices(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
                self.epoch += 1;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Next token batch as a flat [B*T] buffer.
    pub fn next_tokens(&mut self, ds: &TextDataset) -> Vec<i32> {
        let idx = self.next_indices();
        let mut out = Vec::with_capacity(self.batch * ds.seq_len);
        for i in idx {
            out.extend_from_slice(&ds.rows[i]);
        }
        out
    }

    /// Next batch gathered from per-row f32 features (e.g. images).
    pub fn next_f32<T: AsRef<[f32]>>(&mut self, rows: &[T]) -> Vec<f32> {
        let idx = self.next_indices();
        let width = rows[0].as_ref().len();
        let mut out = Vec::with_capacity(self.batch * width);
        for i in idx {
            debug_assert_eq!(rows[i].as_ref().len(), width);
            out.extend_from_slice(rows[i].as_ref());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_fixed_length() {
        let ds = TextDataset::from_texts(
            &["hi".into(), "a much longer sentence here".into()], 12);
        assert!(ds.rows.iter().all(|r| r.len() == 12));
    }

    #[test]
    fn batches_full_size_and_cover_dataset() {
        let mut b = Batcher::new(10, 4, 0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10 {
            let idx = b.next_indices();
            assert_eq!(idx.len(), 4);
            seen.extend(idx);
        }
        assert_eq!(seen.len(), 10);
        assert!(b.epoch >= 3);
    }

    #[test]
    fn epoch_reshuffles() {
        let mut b = Batcher::new(8, 8, 1);
        let e1 = b.next_indices();
        let e2 = b.next_indices();
        assert_ne!(e1, e2); // reshuffled epochs differ (w.h.p. for seed 1)
        let mut s1 = e1.clone();
        let mut s2 = e2.clone();
        s1.sort_unstable();
        s2.sort_unstable();
        assert_eq!(s1, s2);
    }

    #[test]
    fn token_batch_layout() {
        let ds = TextDataset::from_texts(&["ab".into(), "cd".into()], 6);
        let mut b = Batcher::new(2, 2, 2);
        let flat = b.next_tokens(&ds);
        assert_eq!(flat.len(), 12);
    }

    #[test]
    fn f32_batch_layout() {
        let rows = vec![vec![1.0f32; 5], vec![2.0f32; 5], vec![3.0f32; 5]];
        let mut b = Batcher::new(3, 2, 3);
        let flat = b.next_f32(&rows);
        assert_eq!(flat.len(), 10);
    }
}
