//! GSM8K-like synthetic corpus: templated multi-step arithmetic word
//! problems with chain-of-thought answers.
//!
//! Stands in for GSM8K (DESIGN.md §2): the redundancy / routing experiments
//! only need structured reasoning text whose token-level predictability
//! varies across positions, which these problems provide (numbers are hard,
//! connective text is easy — exactly the kind of signal token routers
//! exploit).

use crate::rng::Rng;

const NAMES: &[&str] = &[
    "Alice", "Ben", "Cara", "Dan", "Eve", "Finn", "Gia", "Hugo", "Ivy",
    "Jack", "Kira", "Liam", "Mona", "Nate",
];

const ITEMS: &[&str] = &[
    "apples", "books", "coins", "pens", "cards", "stones", "cakes",
    "shells", "stamps", "marbles",
];

/// One generated problem: question text, chain-of-thought answer text, and
/// the final numeric answer (for exact-match eval).
#[derive(Debug, Clone)]
pub struct Problem {
    pub question: String,
    pub answer: String,
    pub result: i64,
}

impl Problem {
    pub fn full_text(&self) -> String {
        format!("Q: {} A: {}", self.question, self.answer)
    }
}

/// Generate one multi-step problem (2–4 arithmetic steps).
pub fn gen_problem(rng: &mut Rng) -> Problem {
    let name1 = *rng.choose(NAMES);
    let mut name2 = *rng.choose(NAMES);
    while name2 == name1 {
        name2 = *rng.choose(NAMES);
    }
    let item = *rng.choose(ITEMS);
    let steps = rng.range(2, 4);

    let a = rng.range(2, 20);
    let mut total = a;
    let mut q = format!("{name1} has {a} {item}.");
    let mut cot = format!("{name1} starts with {a}.");

    for s in 0..steps {
        match rng.below(4) {
            0 => {
                let b = rng.range(2, 15);
                total += b;
                q.push_str(&format!(" {name2} gives {name1} {b} more."));
                cot.push_str(&format!(" Then {} + {} = {}.", total - b, b, total));
            }
            1 if total >= 2 => {
                let b = rng.range(1, total - 1);
                total -= b;
                q.push_str(&format!(" {name1} loses {b} of them."));
                cot.push_str(&format!(" Then {} - {} = {}.", total + b, b, total));
            }
            2 => {
                let b = rng.range(2, 4);
                total *= b;
                q.push_str(&format!(
                    " {name1} then finds {b} times what they had."));
                cot.push_str(&format!(" Then {} * {} = {}.", total / b, b, total));
            }
            _ => {
                let b = rng.range(2, 4);
                let before = total;
                total /= b;
                q.push_str(&format!(
                    " {name1} splits them into {b} equal groups and keeps one."));
                cot.push_str(&format!(" Then {before} / {b} = {total}."));
            }
        }
        if s == steps - 1 {
            q.push_str(&format!(" How many {item} does {name1} have?"));
        }
    }
    cot.push_str(&format!(" The answer is {total}."));
    Problem { question: q, answer: cot, result: total }
}

/// A deterministic dataset of `n` problems from `seed`.
pub fn dataset(n: usize, seed: u64) -> Vec<Problem> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| gen_problem(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = dataset(5, 1);
        let b = dataset(5, 1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.full_text(), y.full_text());
            assert_eq!(x.result, y.result);
        }
    }

    #[test]
    fn answers_are_consistent() {
        for p in dataset(50, 2) {
            assert!(p.answer.contains(&format!("The answer is {}.", p.result)));
            assert!(p.result >= 0, "negative count: {}", p.result);
        }
    }

    #[test]
    fn text_is_printable_ascii() {
        for p in dataset(50, 3) {
            assert!(p.full_text().bytes().all(|b| (32..127).contains(&b)));
        }
    }

    #[test]
    fn problems_vary() {
        let d = dataset(20, 4);
        let uniq: std::collections::HashSet<_> =
            d.iter().map(|p| p.question.clone()).collect();
        assert!(uniq.len() > 15);
    }
}
