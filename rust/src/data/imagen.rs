//! ImageNet stand-in: 10 procedural texture classes with per-sample
//! parameter variation.
//!
//! Fig. 8 compares Elasti-ViT routers trained on different *class subsets*
//! of ImageNet; what that experiment needs is a family of visually distinct
//! class-conditional distributions, which these textures provide.  Each
//! sample also records ground-truth attributes (class word, dominant color
//! word, density word) that `capgen` turns into captions and the Fig. 9
//! OpenCHAIR-like metric checks against.

use crate::rng::Rng;

pub const NUM_CLASSES: usize = 10;

pub const CLASS_NAMES: [&str; NUM_CLASSES] = [
    "stripes", "checker", "rings", "gradient", "dots", "cross", "waves",
    "blobs", "grid", "spiral",
];

const COLOR_NAMES: [&str; 6] = ["red", "green", "blue", "yellow", "purple", "cyan"];
const COLORS: [[f32; 3]; 6] = [
    [0.9, 0.15, 0.15],
    [0.15, 0.85, 0.2],
    [0.2, 0.3, 0.95],
    [0.9, 0.85, 0.15],
    [0.7, 0.2, 0.85],
    [0.15, 0.85, 0.85],
];

/// Ground-truth scene description of one generated image.
#[derive(Debug, Clone)]
pub struct Scene {
    pub class: usize,
    pub color: usize,
    /// 0 = sparse/coarse, 1 = dense/fine
    pub dense: bool,
    pub phase: f32,
}

impl Scene {
    pub fn class_name(&self) -> &'static str {
        CLASS_NAMES[self.class]
    }

    pub fn color_name(&self) -> &'static str {
        COLOR_NAMES[self.color]
    }

    pub fn density_name(&self) -> &'static str {
        if self.dense { "dense" } else { "sparse" }
    }
}

/// Generate one `size x size x 3` image (flattened HWC, values in [0,1])
/// of the given class, plus its scene ground truth.
pub fn gen_image(rng: &mut Rng, class: usize, size: usize) -> (Vec<f32>, Scene) {
    let scene = Scene {
        class,
        color: rng.below(COLOR_NAMES.len()),
        dense: rng.chance(0.5),
        phase: rng.f32() * std::f32::consts::TAU,
    };
    let img = render(&scene, size);
    (img, scene)
}

/// Deterministic render of a scene (pure function: same scene -> same image).
pub fn render(scene: &Scene, size: usize) -> Vec<f32> {
    let fg = COLORS[scene.color];
    let bg = [0.08f32, 0.08, 0.1];
    let freq = if scene.dense { 6.0 } else { 3.0 };
    let ph = scene.phase;
    let n = size as f32;
    let mut out = vec![0.0f32; size * size * 3];
    for y in 0..size {
        for x in 0..size {
            let u = x as f32 / n;
            let v = y as f32 / n;
            let cu = u - 0.5;
            let cv = v - 0.5;
            let val: f32 = match scene.class {
                0 => ((u * freq * std::f32::consts::TAU + ph).sin() > 0.0) as u8 as f32,
                1 => {
                    let cx = (u * freq + ph).floor() as i64;
                    let cy = (v * freq).floor() as i64;
                    ((cx + cy) % 2 == 0) as u8 as f32
                }
                2 => {
                    let r = (cu * cu + cv * cv).sqrt();
                    ((r * freq * 2.0 * std::f32::consts::TAU + ph).sin() > 0.0)
                        as u8 as f32
                }
                3 => (u + v) * 0.5,
                4 => {
                    let du = (u * freq + ph / 7.0).fract() - 0.5;
                    let dv = (v * freq).fract() - 0.5;
                    (du * du + dv * dv < 0.05) as u8 as f32
                }
                5 => (cu.abs() < 0.08 || cv.abs() < 0.08) as u8 as f32,
                6 => ((u * freq * std::f32::consts::TAU
                    + (v * freq * 2.0).sin() * 2.0 + ph)
                    .sin() > 0.0) as u8 as f32,
                7 => {
                    // smooth blobs: sum of a few fixed gaussians, phase-shifted
                    let mut s = 0.0;
                    for i in 0..3 {
                        let gx = 0.25 + 0.5 * ((ph + i as f32 * 2.1).sin() * 0.5 + 0.5);
                        let gy = 0.25 + 0.5 * ((ph * 1.3 + i as f32 * 1.7).cos() * 0.5 + 0.5);
                        let d2 = (u - gx) * (u - gx) + (v - gy) * (v - gy);
                        s += (-d2 * freq * 10.0).exp();
                    }
                    s.min(1.0)
                }
                8 => {
                    let lu = (u * freq + ph / 9.0).fract() < 0.15;
                    let lv = (v * freq).fract() < 0.15;
                    (lu || lv) as u8 as f32
                }
                _ => {
                    let r = (cu * cu + cv * cv).sqrt();
                    let a = cv.atan2(cu);
                    ((a + r * freq * 3.0 + ph).sin() > 0.0) as u8 as f32
                }
            };
            let idx = (y * size + x) * 3;
            for c in 0..3 {
                out[idx + c] = bg[c] + (fg[c] - bg[c]) * val;
            }
        }
    }
    out
}

/// A labelled dataset: `n` images of random classes (or a fixed class).
pub fn dataset(n: usize, size: usize, class: Option<usize>, seed: u64)
               -> Vec<(Vec<f32>, Scene)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let c = class.unwrap_or_else(|| rng.below(NUM_CLASSES));
            gen_image(&mut rng, c, size)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixels_in_range() {
        let mut rng = Rng::new(0);
        for c in 0..NUM_CLASSES {
            let (img, _) = gen_image(&mut rng, c, 16);
            assert_eq!(img.len(), 16 * 16 * 3);
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)), "class {c}");
        }
    }

    #[test]
    fn render_is_pure() {
        let s = Scene { class: 2, color: 1, dense: true, phase: 0.7 };
        assert_eq!(render(&s, 24), render(&s, 24));
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean inter-class pixel distance must exceed intra-class distance
        let mut rng = Rng::new(1);
        let size = 16;
        let a1 = render(&Scene { class: 0, color: 0, dense: true, phase: 0.1 }, size);
        let a2 = render(&Scene { class: 0, color: 0, dense: true, phase: 0.4 }, size);
        let b = render(&Scene { class: 1, color: 0, dense: true, phase: 0.1 }, size);
        let dist = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b).powi(2)).sum()
        };
        assert!(dist(&a1, &b) > 0.0);
        let _ = rng.next_u64();
        // same class, different phase should still be closer on average
        // than across classes for most structured patterns
        assert!(dist(&a1, &a2) < dist(&a1, &b) * 4.0);
    }

    #[test]
    fn dataset_fixed_class() {
        for (_, scene) in dataset(10, 8, Some(3), 7) {
            assert_eq!(scene.class, 3);
        }
    }

    #[test]
    fn dataset_deterministic() {
        let a = dataset(5, 8, None, 9);
        let b = dataset(5, 8, None, 9);
        for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
            assert_eq!(ia, ib);
            assert_eq!(sa.class, sb.class);
        }
    }
}
