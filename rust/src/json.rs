//! Minimal JSON codec (parser + serializer).
//!
//! The vendored crate set has no `serde_json`, so the manifest/metrics/
//! results plumbing uses this self-contained implementation.  It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bool, null) and preserves object insertion order, which keeps manifests
//! and result files diffable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Object: insertion-ordered key/value pairs.
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing key {key:?} in JSON object"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1,2,3]`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn obj_from<I: IntoIterator<Item = (String, Value)>>(it: I) -> Value {
        Value::Obj(it.into_iter().collect())
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}",
                  b as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(out)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(out)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let c = self.bump()? as char;
                                lo = lo * 16
                                    + c.to_digit(16)
                                        .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            }
                            code = 0x10000
                                + ((code - 0xD800) << 10)
                                + (lo - 0xDC00);
                        }
                        s.push(char::from_u32(code)
                            .ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    c => bail!("bad escape \\{:?}", c as char),
                },
                c if c < 0x20 => bail!("control char in string"),
                c => {
                    // re-assemble multi-byte UTF-8 sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let extra = if c >= 0xF0 { 3 } else if c >= 0xE0 { 2 } else { 1 };
                        let start = self.pos - 1;
                        for _ in 0..extra {
                            self.bump()?;
                        }
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|e| anyhow!("bad utf8: {e}"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(self.peek(),
                       Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(s.parse::<f64>()
            .map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

// ---------------------------------------------------------------------------
// serialization
// ---------------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v, None, 0);
    s
}

/// Pretty-printed with 1-space indent (matches Python's `indent=1`).
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(&mut s, v, Some(1), 0);
    s
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_value(out, item, indent, depth + 1);
            }
            if !a.is_empty() {
                newline(out, indent, depth);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, item)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline(out, indent, depth + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, item, indent, depth + 1);
            }
            if !o.is_empty() {
                newline(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Sorted-key object builder for deterministic output where order is
/// irrelevant (e.g. metric maps).
pub fn obj_sorted(map: BTreeMap<String, Value>) -> Value {
    Value::Obj(map.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1, 2.5, true, null, "s\"t"], "y": {"z": []}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn preserves_object_order() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }
}
