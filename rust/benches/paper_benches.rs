//! Paper benches (`cargo bench --bench paper_benches [-- <ids>]`):
//! regenerates every table and figure of the paper's evaluation at
//! bench-friendly scale (reduced step counts) and prints the same
//! rows/series the paper reports.  Full-scale runs use the CLI
//! (`elastiformer exp <id> --steps ...`); both write `results/*.{md,csv}`.
//!
//! Requires `make artifacts` plus a cached teacher (trained automatically
//! on first use).  `harness = false`: this is a plain binary.

use elastiformer::experiments::{
    fig2, fig4, fig5, fig6, fig7, fig8, fig9, qualitative, table1,
};

fn want(ids: &[String], id: &str) -> bool {
    ids.is_empty() || ids.iter().any(|x| x == id)
}

/// ELASTIFORMER_BENCH_FAST=1 shrinks distill steps/sweeps further (smoke
/// runs on 1-core CI); the recorded full bench run lives in
/// results/paper_benches_run.txt.
fn fast() -> bool {
    std::env::var("ELASTIFORMER_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn steps(normal: usize) -> usize {
    if fast() { (normal / 3).max(8) } else { normal }
}

fn main() {
    let ids: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--"))
        .collect();
    let t0 = std::time::Instant::now();

    if want(&ids, "table1") {
        println!("\n===== table1: router parameter counts =====");
        match table1::run(&["lm_tiny", "lm_base", "vit_tiny", "vlm_tiny"]) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("table1 failed: {e:#}"),
        }
    }
    if want(&ids, "fig2") {
        println!("\n===== fig2: pruning redundancy =====");
        let opts = fig2::Fig2Opts { groups: 3, ..Default::default() };
        match fig2::run(&opts) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("fig2 failed: {e:#}"),
        }
    }
    if want(&ids, "fig4") {
        println!("\n===== fig4: distillation-loss ablation =====");
        let opts = fig4::Fig4Opts { distill_steps: steps(40), ..Default::default() };
        match fig4::run(&opts) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("fig4 failed: {e:#}"),
        }
    }
    if want(&ids, "fig5") {
        println!("\n===== fig5: Elasti-LLM capacity scaling =====");
        let opts = fig5::Fig5Opts {
            distill_steps: steps(40),
            caps: if fast() { vec![0.5] } else { vec![0.5, 1.0] },
            ..Default::default()
        };
        match fig5::run(&opts) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("fig5 failed: {e:#}"),
        }
    }
    if want(&ids, "fig6") {
        println!("\n===== fig6: LoRA rank rescue =====");
        let opts = fig6::Fig6Opts {
            distill_steps: steps(40),
            token_caps: if fast() { vec![0.5] } else { vec![0.5, 0.9] },
            ranks: vec![0, 1],
            ..Default::default()
        };
        match fig6::run(&opts) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("fig6 failed: {e:#}"),
        }
    }
    if want(&ids, "fig7") {
        println!("\n===== fig7: Elasti-ViT scaling (all vs even layers) =====");
        let opts = fig7::Fig7Opts {
            distill_steps: steps(30),
            caps: vec![0.5],
            ..Default::default()
        };
        match fig7::run(&opts) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("fig7 failed: {e:#}"),
        }
    }
    if want(&ids, "fig8") {
        println!("\n===== fig8: router similarity across domains =====");
        let opts = fig8::Fig8Opts {
            distill_steps: steps(25),
            n_classes: if fast() { 3 } else { 4 },
            ..Default::default()
        };
        match fig8::run(&opts) {
            Ok((t, _)) => t.print(),
            Err(e) => eprintln!("fig8 failed: {e:#}"),
        }
    }
    if want(&ids, "fig9") {
        println!("\n===== fig9: Elasti-VLM image-token capacity =====");
        let opts = fig9::Fig9Opts {
            distill_steps: steps(30),
            caps: if fast() { vec![0.5] } else { vec![0.5, 1.0] },
            n_eval_images: if fast() { 8 } else { 16 },
            ..Default::default()
        };
        match fig9::run(&opts) {
            Ok(t) => t.print(),
            Err(e) => eprintln!("fig9 failed: {e:#}"),
        }
    }
    if want(&ids, "qualitative") {
        println!("\n===== figs 10-12: qualitative =====");
        let opts = qualitative::QualOpts {
            distill_steps: steps(30),
            ..Default::default()
        };
        if let Err(e) = qualitative::run(&opts) {
            eprintln!("qualitative failed: {e:#}");
        }
    }
    println!("\npaper_benches done in {:.1}s (tables under results/)",
             t0.elapsed().as_secs_f64());
}
