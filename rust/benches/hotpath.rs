//! Hot-path microbenchmarks (`cargo bench --bench hotpath`):
//! wall-clock of the request-path executables and of the L3 substrates,
//! feeding EXPERIMENTS.md §Perf.
//!
//! Benchmarked:
//!   * serving pipeline overhead (admission/controller/batcher/workers)
//!     over the hermetic SimExecutor — shared single-deque queue vs the
//!     sharded work-stealing queue, per worker count; written as both a
//!     text table and the machine-readable `BENCH_serving.json` at the
//!     repo root (the cross-PR perf-trajectory record)
//!   * serve_cap{25,50,75,100} — real token-compaction speedup per tier
//!   * teacher_forward vs elastic_forward (pallas interpret) overhead
//!   * pretrain / distill step wall-clock
//!   * host substrates: literal round-trip size, batcher, tokenizer, JSON

use elastiformer::bench::{fmt_f, Bencher, Table};
use elastiformer::coordinator::serving::{sim, SimSpec};
use elastiformer::coordinator::trainer::{Caps, Trainer};
use elastiformer::data::{mathgen, textgen, Batcher, TextDataset, Tokenizer};
use elastiformer::experiments::common::Ctx;
use elastiformer::runtime::client::Arg;

fn main() {
    match run() {
        Ok(()) => {}
        Err(e) => {
            eprintln!("hotpath bench failed: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Engine overhead at N workers: saturating synthetic load through
/// near-zero-latency sim executors, so wall-clock is dominated by the
/// host pipeline (admission queue, controller, batch formation).  Each
/// worker count runs twice — `shared` pins every worker on one deque
/// (the pre-sharding topology), `sharded` gives each worker its own
/// shard with work stealing — plus one heterogeneous fast/slow
/// two-class point (per-class capacity controllers), and everything
/// lands in `BENCH_serving.json` at the repo root.
fn sim_pipeline_bench() -> anyhow::Result<()> {
    println!("--- serving pipeline (SimExecutor, hermetic) ---");
    let n = 2048usize;
    let spec = SimSpec {
        base_ms: 0.05,
        ms_per_capacity: 0.05,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let mut rows: Vec<sim::BenchRow> = Vec::new();
    for workers in [1usize, 2, 4] {
        for (label, shards) in [("shared", 1usize), ("sharded", workers)] {
            if label == "sharded" && shards == 1 {
                continue; // identical to shared at 1 worker
            }
            let report = sim::pipeline_point(spec, workers, shards, n)?;
            println!("sim_serving_{label}_w{workers}   \
                      {:>8.0} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms  \
                      mean cap {:.2}",
                     report.throughput_rps(), report.latency_p(0.5),
                     report.latency_p(0.99), report.mean_capacity());
            rows.push(sim::BenchRow { queue: label, workers, shards,
                                      classes: String::new(),
                                      fault_rate: 0.0, submitted: 0,
                                      trace_overhead: 0.0, report });
        }
    }
    // heterogeneous topology: 2 fast workers + 2 slow (4x latency)
    // workers behind the same sharded queue, one controller per class
    let slow = SimSpec {
        base_ms: spec.base_ms * 4.0,
        ms_per_capacity: spec.ms_per_capacity * 4.0,
        ..spec
    };
    let report = sim::pipeline_point_classes(
        &[("fast", spec, 2), ("slow", slow, 2)], 4, n)?;
    println!("sim_serving_hetero_fast2_slow2   \
              {:>8.0} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms  \
              mean cap {:.2}",
             report.throughput_rps(), report.latency_p(0.5),
             report.latency_p(0.99), report.mean_capacity());
    rows.push(sim::BenchRow {
        queue: "hetero",
        workers: 4,
        shards: 4,
        classes: "fast=2:slow=2".into(),
        fault_rate: 0.0,
        submitted: 0,
        trace_overhead: 0.0,
        report,
    });
    // streaming decode: 64 concurrent sessions x 16 tokens through
    // submit_stream — continuous batching with a per-step tier
    // decision; tokens/s is the row's headline figure.  Window
    // preparation is modeled (recomputed row = O(seq_len), arena-hit
    // row = O(1)), so the session arena's saving shows up in tokens/s
    // and the row records its hit rate.
    let (sessions, decode_steps) = (64usize, 16usize);
    let stream_spec =
        SimSpec { recompute_ms_per_token: 0.002, ..spec };
    let report = sim::streaming_point(stream_spec, 4, 4, sessions,
                                      decode_steps)?;
    let first_token = if report.stream_done.is_empty() {
        0.0
    } else {
        report.stream_done.iter().map(|s| s.first_token_ms).sum::<f64>()
            / report.stream_done.len() as f64
    };
    println!("sim_serving_streaming_s{sessions}x{decode_steps}   \
              {:>8.0} tok/s  mean first-token {:>6.2} ms  \
              sessions {}/{}  arena hit rate {:.1}%",
             report.tokens_per_s(), first_token,
             report.stream_done.len(), report.sessions_started,
             report.cache_hit_rate() * 100.0);
    rows.push(sim::BenchRow {
        queue: "streaming",
        workers: 4,
        shards: 4,
        classes: String::new(),
        fault_rate: 0.0,
        submitted: 0,
        trace_overhead: 0.0,
        report,
    });
    // speculative decode: the same sessions, but each admission
    // drafts up to 4 tokens at the cheapest floored tier and verifies
    // them in one top-tier pass.  Mild tier-dependent divergence makes
    // acceptance imperfect, so the recorded accept rate is a real
    // figure; tokens-per-admission > 1.0 is the row's headline (plain
    // decode is exactly 1.0 by construction).
    let spec_spec = SimSpec { divergence: 0.05, ..stream_spec };
    let report = sim::speculative_point(spec_spec, 4, 4, sessions,
                                        decode_steps, 4)?;
    println!("sim_serving_speculative_s{sessions}x{decode_steps}_k4   \
              {:>8.0} tok/s  accept {:>5.1}%  {:.2} tok/admission  \
              sessions {}/{}",
             report.tokens_per_s(), report.spec_accept_rate() * 100.0,
             report.tokens_per_admission(), report.stream_done.len(),
             report.sessions_started);
    rows.push(sim::BenchRow {
        queue: "speculative",
        workers: 4,
        shards: 4,
        classes: String::new(),
        fault_rate: 0.0,
        submitted: 0,
        trace_overhead: 0.0,
        report,
    });
    // chaos injection: the speculative workload under a seeded fault
    // plan — 10% transient failures skewed toward cheap tiers, plus
    // one always-poisoned request the quarantine ladder must shed —
    // and the row records availability plus the fault-ladder economy.
    let fault_rate = 0.1;
    let fault_spec = SimSpec {
        fault: elastiformer::coordinator::serving::FaultPlan {
            fail_p: fault_rate,
            tier_bias: 0.5,
            poison_token: 661,
            ..Default::default()
        },
        ..spec_spec
    };
    let (fault_n, fault_sessions) = (256usize, 16usize);
    let report = sim::faults_point(fault_spec, 4, 4, fault_n,
                                   fault_sessions, decode_steps, 4)?;
    let served = report.completions.len() + report.stream_done.len();
    let submitted = fault_n + fault_sessions;
    let (mut retries, mut poisoned, mut respawns) = (0usize, 0usize, 0usize);
    for s in report.fault_sections() {
        retries += s.retries;
        poisoned += s.poisoned;
        respawns += s.respawns;
    }
    println!("sim_serving_faults_p{fault_rate}   \
              availability {:.4}  retries {retries}  \
              poisoned {poisoned}  respawns {respawns}",
             served as f64 / submitted as f64);
    rows.push(sim::BenchRow {
        queue: "faults",
        workers: 4,
        shards: 4,
        classes: String::new(),
        fault_rate,
        submitted,
        trace_overhead: 0.0,
        report,
    });
    // flight recorder: the same saturating one-shot load with the
    // recorder on.  The headline is the traced/untraced req/s ratio —
    // every event site is one branch when tracing is off and one
    // lane-local lock push when on, so the ratio should sit near 1.0;
    // a regression here means the recorder leaked onto the hot path.
    let untraced = sim::pipeline_point(spec, 4, 4, n)?;
    let (report, events, counts) =
        sim::traced_point(spec, 4, 4, n, 0, 0, 0, 1 << 16)?;
    let trace_overhead =
        report.throughput_rps() / untraced.throughput_rps();
    println!("sim_serving_traced_w4   {:>8.0} req/s  \
              ({:.2}x untraced)  {} event(s), {} dropped",
             report.throughput_rps(), trace_overhead, events.len(),
             counts.dropped);
    rows.push(sim::BenchRow {
        queue: "trace",
        workers: 4,
        shards: 4,
        classes: String::new(),
        fault_rate: 0.0,
        submitted: 0,
        trace_overhead,
        report,
    });
    let path = std::path::Path::new(
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json"));
    sim::write_bench_json(path, "benches/hotpath.rs (release)", spec, n,
                          &rows)?;
    println!("(written to BENCH_serving.json)");
    Ok(())
}

fn run() -> anyhow::Result<()> {
    sim_pipeline_bench()?;

    let ctx = match Ctx::load("lm_tiny", 42) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("\nskipping artifact benches (no runtime): {e:#}");
            return Ok(());
        }
    };
    let trainer = Trainer::new(&ctx.rt);
    let params = trainer.init_params("init", 1)?;
    let router0 = trainer.init_params("router_init_r0", 2)?;
    let router8 = trainer.init_params("router_init_r8", 2)?;
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let tok = Tokenizer::new();
    let tokens: Vec<i32> = mathgen::dataset(b, 3)
        .iter()
        .flat_map(|p| tok.encode_padded(&p.full_text(), t))
        .collect();
    let l = ctx.rt.manifest.n_layers();
    let ones = vec![1.0f32; l];
    let caps = Caps::full();

    let entries = [
        "serve_cap25", "serve_cap50", "serve_cap75", "serve_cap100",
        "teacher_forward", "elastic_forward_r0", "elastic_forward_r8",
        "pretrain_step", "distill_step_r0",
    ];
    ctx.rt.warmup(&entries)?;

    let bench = Bencher::default();
    let mut table = Table::new(&["bench", "mean_ms", "p50_ms", "p99_ms",
                                 "throughput"]);
    let mut push = |r: elastiformer::bench::BenchResult, thr: String| {
        println!("{:<26} mean {:>8.2} ms  p50 {:>8.2} ms  p99 {:>8.2} ms",
                 r.name, r.mean_ms(), r.p50.as_secs_f64() * 1e3,
                 r.p99.as_secs_f64() * 1e3);
        table.row(vec![
            r.name.clone(),
            fmt_f(r.mean_ms(), 3),
            fmt_f(r.p50.as_secs_f64() * 1e3, 3),
            fmt_f(r.p99.as_secs_f64() * 1e3, 3),
            thr,
        ]);
    };

    // --- serve tiers: the wall-clock elasticity claim -------------------
    for entry in ["serve_cap100", "serve_cap75", "serve_cap50", "serve_cap25"] {
        let r = bench.run(entry, || {
            ctx.rt
                .exec(entry, &[
                    Arg::F32(&params),
                    Arg::F32(&router0),
                    Arg::I32(&tokens),
                ])
                .unwrap();
        });
        let tput = format!("{:.0} tok/s",
                           r.throughput_per_s((b * t) as f64));
        push(r, tput);
    }

    // --- L3 perf iteration 1: cached-literal dispatch vs naive ----------
    {
        let params_lit = ctx.rt.prepare_arg("serve_cap50", 0,
                                            &Arg::F32(&params))?;
        let router_lit = ctx.rt.prepare_arg("serve_cap50", 1,
                                            &Arg::F32(&router0))?;
        let r = bench.run("serve_cap50_prepared", || {
            let tokens_lit = ctx.rt
                .prepare_arg("serve_cap50", 2, &Arg::I32(&tokens))
                .unwrap();
            ctx.rt
                .exec_prepared("serve_cap50",
                               &[&params_lit, &router_lit, &tokens_lit])
                .unwrap();
        });
        let tput = format!("{:.0} tok/s", r.throughput_per_s((b * t) as f64));
        push(r, tput);
    }

    // --- dense vs elastic (pallas) forward -------------------------------
    let hmask = vec![1.0f32; l * ctx.rt.manifest.n_heads()];
    let r = bench.run("teacher_forward", || {
        ctx.rt
            .exec("teacher_forward", &[
                Arg::F32(&params),
                Arg::I32(&tokens),
                Arg::F32(&hmask),
                Arg::F32(&ones),
                Arg::F32(&ones),
            ])
            .unwrap();
    });
    let tput = format!("{:.0} tok/s", r.throughput_per_s((b * t) as f64));
    push(r, tput);
    for (entry, router) in [("elastic_forward_r0", &router0),
                            ("elastic_forward_r8", &router8)] {
        let r = bench.run(entry, || {
            ctx.rt
                .exec(entry, &[
                    Arg::F32(&params),
                    Arg::F32(router),
                    Arg::I32(&tokens),
                    Arg::F32(&caps.0),
                    Arg::F32(&ones),
                    Arg::ScalarF32(0.0),
                ])
                .unwrap();
        });
        let tput = format!("{:.0} tok/s", r.throughput_per_s((b * t) as f64));
        push(r, tput);
    }

    // --- train steps ------------------------------------------------------
    {
        let m = vec![0.0f32; params.len()];
        let v = vec![0.0f32; params.len()];
        let r = bench.run("pretrain_step", || {
            ctx.rt
                .exec("pretrain_step", &[
                    Arg::F32(&params),
                    Arg::F32(&m),
                    Arg::F32(&v),
                    Arg::ScalarI32(0),
                    Arg::ScalarF32(1e-3),
                    Arg::I32(&tokens),
                ])
                .unwrap();
        });
        let tput = format!("{:.0} tok/s", r.throughput_per_s((b * t) as f64));
        push(r, tput);
        let rm = vec![0.0f32; router0.len()];
        let rv = vec![0.0f32; router0.len()];
        let r = bench.run("distill_step_r0", || {
            ctx.rt
                .exec("distill_step_r0", &[
                    Arg::F32(&params),
                    Arg::F32(&params),
                    Arg::F32(&router0),
                    Arg::F32(&rm),
                    Arg::F32(&rv),
                    Arg::ScalarI32(0),
                    Arg::ScalarF32(1e-3),
                    Arg::I32(&tokens),
                    Arg::F32(&caps.0),
                    Arg::F32(&ones),
                    Arg::ScalarF32(1.0),
                ])
                .unwrap();
        });
        let tput = format!("{:.0} tok/s", r.throughput_per_s((b * t) as f64));
        push(r, tput);
    }

    // --- host substrates --------------------------------------------------
    {
        let texts = textgen::dataset(512, 1);
        let ds = TextDataset::from_texts(&texts, t);
        let mut batcher = Batcher::new(ds.len(), b, 1);
        let r = bench.run("batcher_next_tokens", || {
            std::hint::black_box(batcher.next_tokens(&ds));
        });
        let tput = format!("{:.0} batches/s", r.throughput_per_s(1.0));
        push(r, tput);

        let doc = texts.join(" ");
        let tokz = Tokenizer::new();
        let r = bench.run("tokenizer_encode_50kB", || {
            std::hint::black_box(tokz.encode(&doc));
        });
        let tput = format!("{:.1} MB/s",
                           r.throughput_per_s(doc.len() as f64) / 1e6);
        push(r, tput);

        let man_path = format!("{}/lm_tiny/manifest.json",
                               elastiformer::experiments::common::artifacts_dir());
        let man_text = std::fs::read_to_string(man_path)?;
        let r = bench.run("json_parse_manifest", || {
            std::hint::black_box(
                elastiformer::json::parse(&man_text).unwrap());
        });
        let tput = format!("{:.1} MB/s",
                           r.throughput_per_s(man_text.len() as f64) / 1e6);
        push(r, tput);
    }

    elastiformer::metrics::write_file(
        elastiformer::experiments::common::results_dir()
            .join("hotpath_bench.csv"),
        &table.to_csv())?;
    println!("\n(written to results/hotpath_bench.csv)");
    Ok(())
}
