//! Stub of the `xla` crate API surface used by `elastiformer::runtime`.
//!
//! Host-side literals (construction, reshape, extraction) are
//! implemented for real so code that only marshals data keeps working.
//! Everything that would need the PJRT runtime fails at the earliest
//! possible point — [`PjRtClient::cpu`] — with an error explaining how
//! to swap in the real backend.  See Cargo.toml for the rationale.

use std::fmt;

/// Stub error: a message, `Display`-compatible with how the runtime
/// layer formats backend errors.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla stub — the vendored xla_extension runtime is not \
         present in this build; point the `xla` path dependency at a \
         real xla-rs checkout to execute artifacts"))
}

/// Element types a [`Literal`] can hold.
pub trait Element: Copy {
    fn build(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn build(data: Vec<Self>, dims: Vec<i64>) -> Literal {
        Literal::F32 { data, dims }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl Element for i32 {
    fn build(data: Vec<Self>, dims: Vec<i64>) -> Literal {
        Literal::I32 { data, dims }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not i32: {other:?}"))),
        }
    }
}

/// Host-side literal: typed buffer + dims, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from any slice-like of elements.
    pub fn vec1<T, S>(data: &S) -> Literal
    where
        T: Element,
        S: AsRef<[T]> + ?Sized,
    {
        let data = data.as_ref().to_vec();
        let n = data.len() as i64;
        T::build(data, vec![n])
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: Element>(x: T) -> Literal {
        T::build(vec![x], Vec::new())
    }

    fn len(&self) -> Result<i64> {
        match self {
            Literal::F32 { data, .. } => Ok(data.len() as i64),
            Literal::I32 { data, .. } => Ok(data.len() as i64),
            Literal::Tuple(_) => {
                Err(Error("tuple literal has no element count".into()))
            }
        }
    }

    /// Reshape to `dims` (element count must match; `&[]` is scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product(); // empty product = 1 = scalar
        let have = self.len()?;
        if want != have {
            return Err(Error(format!(
                "reshape {have} elements into {dims:?} ({want})")));
        }
        let dims = dims.to_vec();
        Ok(match self {
            Literal::F32 { data, .. } => {
                Literal::F32 { data: data.clone(), dims }
            }
            Literal::I32 { data, .. } => {
                Literal::I32 { data: data.clone(), dims }
            }
            Literal::Tuple(_) => unreachable!("len() rejected tuples"),
        })
    }

    /// Extract the host buffer as a typed vector.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Err(Error(format!("not a tuple literal: {other:?}"))),
        }
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        // parsing HLO text needs the real extension; fail with context
        Err(unavailable(&format!("parse HLO text {path:?}")))
    }
}

/// Computation handle (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.clone() }
    }
}

/// PJRT client (construction always fails in the stub — this is the
/// single choke point every artifact-dependent path flows through).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("create PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Compiled executable handle (never constructible via the stub client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0][..]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_accepts_double_refs_and_arrays() {
        // the runtime layer passes `&&[T]` (match-binding) and `&[T; 1]`
        let row: &[i32] = &[7, 8];
        let a = Literal::vec1(&row);
        let b = Literal::vec1(&[7i32, 8]);
        assert_eq!(a, b);
    }

    #[test]
    fn scalar_reshape_to_empty_dims() {
        let s = Literal::scalar(3.5f32);
        assert_eq!(s.reshape(&[]).unwrap().to_vec::<f32>().unwrap(),
                   vec![3.5]);
        assert!(s.reshape(&[2]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![Literal::scalar(1i32),
                                    Literal::scalar(2i32)]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }

    #[test]
    fn runtime_paths_fail_with_stub_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
