"""AOT compiler: lower every L2 entrypoint to HLO **text** + a manifest.

This is the only place Python touches the pipeline; ``make artifacts`` runs
it once and the Rust coordinator is self-contained afterwards.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's runtime
(xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Lowering goes through stablehlo ->
XlaComputation with ``return_tuple=True``; the Rust side unwraps the tuple.

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--config lm_tiny ...]

Outputs, per config:
    artifacts/<config>/<entry>.hlo.txt
    artifacts/<config>/manifest.json     (shapes, param tables, entry specs)
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, params, train

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


class Entry:
    """One AOT entrypoint: a jax function plus named example arguments."""

    def __init__(self, name, fn, args):
        self.name = name
        self.fn = fn
        self.args = args  # list of (arg_name, ShapeDtypeStruct)

    def lower(self):
        # keep_unused: an entry like serve_cap100 (bypass tier) ignores its
        # router argument; without this flag jit would drop the parameter
        # from the lowered ENTRY signature and break the Rust-side contract.
        arg_specs = [s for _, s in self.args]
        return jax.jit(self.fn, keep_unused=True).lower(*arg_specs)

    def out_specs(self):
        arg_specs = [s for _, s in self.args]
        out = jax.eval_shape(self.fn, *arg_specs)
        leaves = jax.tree_util.tree_leaves(out)
        return leaves

    def manifest(self):
        outs = self.out_specs()
        return {
            "name": self.name,
            "args": [
                {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                for n, s in self.args
            ],
            "outputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in outs
            ],
        }


def _seeded_key(seed):
    return jax.random.PRNGKey(seed)


# ---------------------------------------------------------------------------
# entry builders per model family
# ---------------------------------------------------------------------------

def lm_entries(cfg):
    tspec = params.lm_teacher_spec(cfg)
    ranks = sorted({0, 1, cfg.lora_rank})
    rspecs = {r: params.lm_router_spec(cfg, lora_rank=r) for r in ranks}

    b, t, v = cfg.batch, cfg.seq_len, cfg.vocab
    l, h, m = cfg.n_layers, cfg.n_heads, cfg.n_experts
    nt = tspec.total

    entries = []
    entries.append(Entry(
        "init",
        lambda seed: tspec.init_flat(_seeded_key(seed)),
        [("seed", spec((), I32))]))

    for r, rs in rspecs.items():
        entries.append(Entry(
            f"router_init_r{r}",
            (lambda rs_: lambda seed: rs_.init_flat(_seeded_key(seed)))(rs),
            [("seed", spec((), I32))]))

    entries.append(Entry(
        "pretrain_step",
        lambda P, M, V, step, lr, tok: train.lm_pretrain_step(
            tspec, cfg, P, M, V, step, lr, tok),
        [("params", spec((nt,))), ("m", spec((nt,))), ("v", spec((nt,))),
         ("step", spec((), I32)), ("lr", spec(())),
         ("tokens", spec((b, t), I32))]))

    entries.append(Entry(
        "teacher_forward",
        lambda P, tok, hm, ao, mo: train.lm_teacher_forward(
            tspec, cfg, P, tok, hm, ao, mo),
        [("params", spec((nt,))), ("tokens", spec((b, t), I32)),
         ("head_mask", spec((l, h))), ("attn_on", spec((l,))),
         ("mlp_on", spec((l,)))]))

    for r, rs in rspecs.items():
        nr = rs.total
        entries.append(Entry(
            f"elastic_forward_r{r}",
            (lambda rs_, r_: lambda P, R, tok, caps, le, mode:
                train.lm_elastic_forward(
                    tspec, rs_, cfg, P, R, tok, caps, le, mode,
                    use_pallas=cfg.use_pallas, lora_rank=r_))(rs, r),
            [("params", spec((nt,))), ("router", spec((nr,))),
             ("tokens", spec((b, t), I32)), ("caps", spec((4,))),
             ("layer_en", spec((l,))), ("mode", spec(()))]))

        entries.append(Entry(
            f"distill_step_r{r}",
            (lambda rs_, r_: lambda Pt, Ps, R, M, V, step, lr, tok, caps, le,
                temp: train.lm_distill_step(
                    tspec, rs_, cfg, Pt, Ps, R, M, V, step, lr, tok, caps,
                    le, temp, loss_type="fwd_topk", lora_rank=r_))(rs, r),
            [("teacher", spec((nt,))), ("student", spec((nt,))),
             ("router", spec((nr,))), ("m", spec((rs.total,))),
             ("v", spec((rs.total,))), ("step", spec((), I32)),
             ("lr", spec(())), ("tokens", spec((b, t), I32)),
             ("caps", spec((4,))), ("layer_en", spec((l,))),
             ("temp", spec(()))]))

    # Fig. 4: distillation-loss ablation (rank = cfg.lora_rank, noised
    # student supplied by the Rust driver).  fwd_topk == distill_step_r{R}.
    if cfg.name == "lm_tiny":
        rs = rspecs[cfg.lora_rank]
        for lt in configs.FIG4_LOSSES:
            if lt == "fwd_topk":
                continue  # identical to distill_step_r{lora_rank}
            entries.append(Entry(
                f"distill_fig4_{lt}",
                (lambda lt_: lambda Pt, Ps, R, M, V, step, lr, tok, caps, le,
                    temp: train.lm_distill_step(
                        tspec, rs, cfg, Pt, Ps, R, M, V, step, lr, tok, caps,
                        le, temp, loss_type=lt_,
                        lora_rank=cfg.lora_rank))(lt),
                [("teacher", spec((nt,))), ("student", spec((nt,))),
                 ("router", spec((rs.total,))), ("m", spec((rs.total,))),
                 ("v", spec((rs.total,))), ("step", spec((), I32)),
                 ("lr", spec(())), ("tokens", spec((b, t), I32)),
                 ("caps", spec((4,))), ("layer_en", spec((l,))),
                 ("temp", spec(()))]))

    # Static-capacity serving tiers (real token gather; rank-0 router spec).
    rs0 = rspecs[0]
    for tier in configs.SERVE_TIERS:
        entries.append(Entry(
            f"serve_cap{int(round(tier * 100))}",
            (lambda c: lambda P, R, tok: train.lm_serve_forward(
                tspec, rs0, cfg, P, R, tok, c))(tier),
            [("params", spec((nt,))), ("router", spec((rs0.total,))),
             ("tokens", spec((b, t), I32))]))

    tables = {"teacher_params": tspec.manifest(),
              "router_params": {str(r): rs.manifest()
                                for r, rs in rspecs.items()}}
    return entries, tables


def vit_entries(cfg):
    tspec = params.vit_teacher_spec(cfg)
    rspec = params.vit_router_spec(cfg)
    b = cfg.batch
    img = cfg.img_size * cfg.img_size * cfg.channels
    l = cfg.n_layers
    nt, nr = tspec.total, rspec.total

    entries = [
        Entry("init", lambda seed: tspec.init_flat(_seeded_key(seed)),
              [("seed", spec((), I32))]),
        Entry("router_init", lambda seed: rspec.init_flat(_seeded_key(seed)),
              [("seed", spec((), I32))]),
        Entry("pretrain_step",
              lambda P, M, V, step, lr, im: train.vit_pretrain_step(
                  tspec, cfg, P, M, V, step, lr, im),
              [("params", spec((nt,))), ("m", spec((nt,))),
               ("v", spec((nt,))), ("step", spec((), I32)),
               ("lr", spec(())), ("images", spec((b, img)))]),
        Entry("teacher_forward",
              lambda P, im: train.vit_teacher_forward(tspec, cfg, P, im),
              [("params", spec((nt,))), ("images", spec((b, img)))]),
        Entry("elastic_forward",
              lambda P, R, im, caps, le, mode: train.vit_elastic_forward(
                  tspec, rspec, cfg, P, R, im, caps, le, mode,
                  use_pallas=cfg.use_pallas),
              [("params", spec((nt,))), ("router", spec((nr,))),
               ("images", spec((b, img))), ("caps", spec((4,))),
               ("layer_en", spec((l,))), ("mode", spec(()))]),
        Entry("distill_step",
              lambda P, R, M, V, step, lr, im, caps, le:
                  train.vit_distill_step(tspec, rspec, cfg, P, R, M, V,
                                         step, lr, im, caps, le),
              [("params", spec((nt,))), ("router", spec((nr,))),
               ("m", spec((nr,))), ("v", spec((nr,))),
               ("step", spec((), I32)), ("lr", spec(())),
               ("images", spec((b, img))), ("caps", spec((4,))),
               ("layer_en", spec((l,)))]),
    ]
    tables = {"teacher_params": tspec.manifest(),
              "router_params": {"linear": rspec.manifest()}}
    return entries, tables


def vlm_entries(cfg):
    tspec = params.vlm_teacher_spec(cfg)
    rspec_lin = params.vlm_router_spec(cfg, mlp_router=False)
    rspec_mlp = params.vlm_router_spec(cfg, mlp_router=True)
    b = cfg.batch
    img = cfg.img_size * cfg.img_size * cfg.channels
    tl = cfg.text_len
    nt = tspec.total

    entries = [
        Entry("init", lambda seed: tspec.init_flat(_seeded_key(seed)),
              [("seed", spec((), I32))]),
        Entry("pretrain_step",
              lambda P, M, V, step, lr, im, tx: train.vlm_pretrain_step(
                  tspec, cfg, P, M, V, step, lr, im, tx),
              [("params", spec((nt,))), ("m", spec((nt,))),
               ("v", spec((nt,))), ("step", spec((), I32)),
               ("lr", spec(())), ("images", spec((b, img))),
               ("texts", spec((b, tl), I32))]),
        Entry("teacher_forward",
              lambda P, im, tx: train.vlm_teacher_forward(
                  tspec, cfg, P, im, tx),
              [("params", spec((nt,))), ("images", spec((b, img))),
               ("texts", spec((b, tl), I32))]),
    ]
    for kind, rs, is_mlp in (("lin", rspec_lin, False),
                             ("mlp", rspec_mlp, True)):
        nr = rs.total
        entries.append(Entry(
            f"router_init_{kind}",
            (lambda rs_: lambda seed: rs_.init_flat(_seeded_key(seed)))(rs),
            [("seed", spec((), I32))]))
        entries.append(Entry(
            f"elastic_forward_{kind}",
            (lambda rs_, im_: lambda P, R, im, tx, cap, mode:
                train.vlm_elastic_forward(tspec, rs_, cfg, P, R, im, tx,
                                          cap, mode, im_))(rs, is_mlp),
            [("params", spec((nt,))), ("router", spec((nr,))),
             ("images", spec((b, img))), ("texts", spec((b, tl), I32)),
             ("capacity", spec(())), ("mode", spec(()))]))
        entries.append(Entry(
            f"distill_step_{kind}",
            (lambda rs_, im_: lambda P, R, M, V, step, lr, im, tx, cap, temp:
                train.vlm_distill_step(tspec, rs_, cfg, P, R, M, V, step,
                                       lr, im, tx, cap, temp, im_))(rs, is_mlp),
            [("params", spec((nt,))), ("router", spec((nr,))),
             ("m", spec((nr,))), ("v", spec((nr,))),
             ("step", spec((), I32)), ("lr", spec(())),
             ("images", spec((b, img))), ("texts", spec((b, tl), I32)),
             ("capacity", spec(())), ("temp", spec(()))]))
    tables = {"teacher_params": tspec.manifest(),
              "router_params": {"linear": rspec_lin.manifest(),
                                "mlp": rspec_mlp.manifest()}}
    return entries, tables


BUILDERS = {"lm": lm_entries, "vit": vit_entries, "vlm": vlm_entries}


def _source_fingerprint():
    """Hash of every .py under compile/ — drives make-style staleness."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def build_config(cfg, out_dir, force=False):
    cfg_dir = os.path.join(out_dir, cfg.name)
    os.makedirs(cfg_dir, exist_ok=True)
    fp = _source_fingerprint()
    man_path = os.path.join(cfg_dir, "manifest.json")
    if not force and os.path.exists(man_path):
        try:
            with open(man_path) as f:
                if json.load(f).get("fingerprint") == fp:
                    print(f"[aot] {cfg.name}: up to date")
                    return
        except Exception:
            pass

    entries, tables = BUILDERS[cfg.kind](cfg)
    man_entries = {}
    for e in entries:
        path = os.path.join(cfg_dir, f"{e.name}.hlo.txt")
        print(f"[aot] lowering {cfg.name}/{e.name} ...", flush=True)
        text = to_hlo_text(e.lower())
        with open(path, "w") as f:
            f.write(text)
        man_entries[e.name] = e.manifest()
        man_entries[e.name]["file"] = f"{e.name}.hlo.txt"

    manifest = {
        "fingerprint": fp,
        "config": cfg.to_dict(),
        "entries": man_entries,
        **tables,
    }
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] {cfg.name}: wrote {len(entries)} artifacts + manifest")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts"))
    ap.add_argument("--config", action="append", default=None,
                    help="config name(s); default = the standard build set")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cfgs = configs.DEFAULT_BUILD if args.config is None else \
        [configs.BY_NAME[n] for n in args.config]
    for cfg in cfgs:
        build_config(cfg, os.path.abspath(args.out_dir), force=args.force)


if __name__ == "__main__":
    main()
