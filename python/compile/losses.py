"""Self-distillation objectives and auxiliary router losses (paper §4.2).

Distillation variants (Fig. 4 ablation; all take a runtime temperature):
  * fwd_full  — KL(p_teacher || p_student) over the whole vocabulary
  * rev_full  — KL(p_student || p_teacher)
  * fwd_topk  — forward KL over the teacher's top-k tokens + a residual
                bucket (the paper's winner; adopted for LM and VLM)
  * rev_topk  — reverse KL on the same top-k + residual vector

Auxiliary losses:
  * load_balance — Appendix B.2's L_load over parameter-subset routers
  * topk_bce     — Appendix B.1's L_top-k aligning training-time top-k
                   selection with inference-time 0.5 thresholding
  * cosine_distance — the ViT distillation objective (§4.2)
"""

import jax
import jax.numpy as jnp

EPS = 1e-8


def _log_softmax_t(logits, temperature):
    return jax.nn.log_softmax(logits / temperature, axis=-1)


def kl_full(teacher_logits, student_logits, temperature, reverse=False):
    """KL divergence over the full vocabulary, averaged over positions.

    forward (reverse=False): KL(p_t || p_s) — mass-covering.
    reverse (reverse=True):  KL(p_s || p_t) — mode-seeking.
    """
    lt = _log_softmax_t(teacher_logits, temperature)
    ls = _log_softmax_t(student_logits, temperature)
    if reverse:
        lt, ls = ls, lt
    p = jnp.exp(lt)
    return jnp.mean(jnp.sum(p * (lt - ls), axis=-1))


def kl_topk(teacher_logits, student_logits, temperature, k, reverse=False):
    """Top-k KL [Askell et al. '21 style, paper §4.2].

    The teacher distribution is collapsed to (k+1) buckets: its top-k tokens
    plus a residual; the student's probabilities are evaluated on the same
    token set.  k is static (baked per artifact).

    Implementation note: the bucketing is expressed with a *mask* derived
    from a descending sort threshold rather than `jax.lax.top_k` + gather —
    the `topk` HLO opcode (and batched-operand gathers) post-date the
    xla_extension 0.5.1 runtime the Rust side executes on, while `sort` is
    classic HLO.  KL over {masked tokens} + {residual bucket} is identical
    to KL over {gathered top-k} + {residual}.
    """
    pt = jax.nn.softmax(teacher_logits / temperature, axis=-1)
    ps = jax.nn.softmax(student_logits / temperature, axis=-1)
    # threshold = k-th largest teacher prob; ties may admit a few extra
    # tokens into the bucket, which only tightens the residual.
    sorted_desc = -jnp.sort(-pt, axis=-1)
    thresh = sorted_desc[..., k - 1:k]                      # [..., 1]
    mask = (pt >= thresh).astype(pt.dtype)                  # [..., V]
    pt_m = pt * mask
    ps_m = ps * mask
    rt = jnp.clip(1.0 - jnp.sum(pt_m, axis=-1), EPS, 1.0)
    rs = jnp.clip(1.0 - jnp.sum(ps_m, axis=-1), EPS, 1.0)
    if reverse:
        pt_m, ps_m = ps_m, pt_m
        rt, rs = rs, rt
    # KL over the masked support ...
    pt_c = jnp.clip(pt_m, EPS, 1.0)
    ps_c = jnp.clip(ps_m, EPS, 1.0)
    kl_main = jnp.sum(mask * pt_c * (jnp.log(pt_c) - jnp.log(ps_c)), axis=-1)
    # ... plus the residual bucket.
    kl_res = rt * (jnp.log(rt) - jnp.log(rs))
    return jnp.mean(kl_main + kl_res)


def distill_loss(teacher_logits, student_logits, temperature, loss_type, topk):
    """Dispatch on the static loss_type string (one AOT artifact each)."""
    if loss_type == "fwd_full":
        return kl_full(teacher_logits, student_logits, temperature, reverse=False)
    if loss_type == "rev_full":
        return kl_full(teacher_logits, student_logits, temperature, reverse=True)
    if loss_type == "fwd_topk":
        return kl_topk(teacher_logits, student_logits, temperature, topk, reverse=False)
    if loss_type == "rev_topk":
        return kl_topk(teacher_logits, student_logits, temperature, topk, reverse=True)
    raise ValueError(f"unknown loss_type {loss_type}")


def cosine_distance(student_tokens, teacher_tokens):
    """Mean 1 - cos(student, teacher) over token embeddings ([..., T, D])."""
    s = student_tokens / (jnp.linalg.norm(student_tokens, axis=-1, keepdims=True) + EPS)
    t = teacher_tokens / (jnp.linalg.norm(teacher_tokens, axis=-1, keepdims=True) + EPS)
    return jnp.mean(1.0 - jnp.sum(s * t, axis=-1))


def cosine_similarity(a, b):
    """Mean cosine similarity over the token axis ([..., T, D] -> [...])."""
    an = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + EPS)
    bn = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + EPS)
    return jnp.mean(jnp.sum(an * bn, axis=-1), axis=-1)


def load_balance(router_w, mask):
    """Appendix B.2 load-balancing loss for parameter-subset routers.

    router_w: [..., T, M]  M*softmax routing weights (sum to M per token).
    mask:     [..., T, M]  boolean top-k selection.

    L = M * sum_m f_m * P_m  with f_m = selection frequency of expert m and
    P_m = mean routing probability of expert m (switch-transformer form of
    "count(top-k) * R(X)_m").  Minimized at uniform utilization.
    """
    m = router_w.shape[-1]
    probs = router_w / jnp.float32(m)          # back to a distribution
    f = jnp.mean(mask.astype(jnp.float32), axis=-2)   # [..., M]
    p = jnp.mean(probs, axis=-2)                      # [..., M]
    return jnp.float32(m) * jnp.mean(jnp.sum(f * p, axis=-1))


def topk_bce(scores, mask):
    """Appendix B.1 auxiliary BCE aligning router scores with top-k labels.

    scores: [..., T] sigmoid router scores; mask: [..., T] top-k selection
    (treated as constant labels — gradients flow only through scores).
    """
    y = jax.lax.stop_gradient(mask.astype(jnp.float32))
    # f32-safe clip: 1 - 1e-8 rounds back to 1.0 in f32, which lets a
    # saturated router sigmoid produce log(0) = -inf (observed as NaN
    # losses once the teacher is strong and scores pin to 1).
    s = jnp.clip(scores, 1e-6, 1.0 - 1e-6)
    return -jnp.mean(y * jnp.log(s) + (1.0 - y) * jnp.log(1.0 - s))


def cross_entropy(logits, targets, pad_id=0):
    """Next-token CE, ignoring pad targets. logits [..., T, V], targets [..., T]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    w = (targets != pad_id).astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def top1_match(logits_a, logits_b, targets, pad_id=0):
    """Fraction of non-pad positions where both models' argmax agrees."""
    a = jnp.argmax(logits_a, axis=-1)
    b = jnp.argmax(logits_b, axis=-1)
    w = (targets != pad_id).astype(jnp.float32)
    return jnp.sum((a == b).astype(jnp.float32) * w) / jnp.maximum(jnp.sum(w), 1.0)
