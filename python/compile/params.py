"""Flat-parameter layout shared between JAX (build time) and Rust (run time).

Every model travels through PJRT as a single flat ``f32[N]`` buffer.  A
``ParamSpec`` assigns each named tensor a static (offset, shape) slot; the
same table is serialized into ``manifest.json`` so the Rust side can
checkpoint, inspect, noise or surgically edit individual tensors without
re-deriving any layout logic.

Two specs exist per model family:
  * the *teacher* spec — the frozen pretrained parameters, and
  * the *router* spec — ElastiFormer's trainable routing modules (+ LoRA),
    which is what ``distill_step`` optimizes.
"""

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import LMConfig, ViTConfig, VLMConfig


class ParamSpec:
    """Ordered (name, shape, init) table with static flat offsets.

    ``init`` is one of:
      "zeros" | "ones" | ("normal", std) | ("uniform_pm", bound) |
      ("const", value)
    """

    def __init__(self):
        self.entries: List[Tuple[str, Tuple[int, ...], object]] = []
        self.offsets: Dict[str, int] = {}
        self.shapes: Dict[str, Tuple[int, ...]] = {}
        self.total = 0

    def add(self, name: str, shape: Tuple[int, ...], init="zeros"):
        assert name not in self.offsets, f"duplicate param {name}"
        size = int(np.prod(shape)) if shape else 1
        self.entries.append((name, tuple(shape), init))
        self.offsets[name] = self.total
        self.shapes[name] = tuple(shape)
        self.total += size
        return self

    def get(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        """Static slice + reshape of one named tensor out of the flat buffer."""
        off = self.offsets[name]
        shape = self.shapes[name]
        size = int(np.prod(shape)) if shape else 1
        return jax.lax.slice(flat, (off,), (off + size,)).reshape(shape)

    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        return {name: self.get(flat, name) for name, _, _ in self.entries}

    def init_flat(self, key: jax.Array) -> jnp.ndarray:
        """Initial flat parameter vector (used by the AOT ``init`` artifact)."""
        parts = []
        for name, shape, init in self.entries:
            size = int(np.prod(shape)) if shape else 1
            key, sub = jax.random.split(key)
            if init == "zeros":
                parts.append(jnp.zeros((size,), jnp.float32))
            elif init == "ones":
                parts.append(jnp.ones((size,), jnp.float32))
            elif isinstance(init, tuple) and init[0] == "normal":
                parts.append(init[1] * jax.random.normal(sub, (size,), jnp.float32))
            elif isinstance(init, tuple) and init[0] == "uniform_pm":
                parts.append(jax.random.uniform(
                    sub, (size,), jnp.float32, -init[1], init[1]))
            elif isinstance(init, tuple) and init[0] == "const":
                parts.append(jnp.full((size,), init[1], jnp.float32))
            else:  # pragma: no cover - spec bug
                raise ValueError(f"unknown init {init!r} for {name}")
        return jnp.concatenate(parts)

    def manifest(self) -> list:
        """JSON-ready layout table for the Rust side."""
        out = []
        for name, shape, _ in self.entries:
            out.append({
                "name": name,
                "shape": list(shape),
                "offset": self.offsets[name],
                "size": int(np.prod(shape)) if shape else 1,
            })
        return out


# ---------------------------------------------------------------------------
# teacher specs
# ---------------------------------------------------------------------------

def _block(spec: ParamSpec, prefix: str, d: int, f: int, std: float):
    """One pre-norm transformer block (RMSNorm / MHA / RMSNorm / MLP)."""
    spec.add(f"{prefix}.ln1", (d,), "ones")
    for p in ("q", "k", "v", "o"):
        spec.add(f"{prefix}.{p}_w", (d, d), ("normal", std))
        spec.add(f"{prefix}.{p}_b", (d,), "zeros")
    spec.add(f"{prefix}.ln2", (d,), "ones")
    spec.add(f"{prefix}.mlp_w1", (d, f), ("normal", std))
    spec.add(f"{prefix}.mlp_b1", (f,), "zeros")
    spec.add(f"{prefix}.mlp_w2", (f, d), ("normal", std / math.sqrt(2.0)))
    spec.add(f"{prefix}.mlp_b2", (d,), "zeros")


def lm_teacher_spec(cfg: LMConfig) -> ParamSpec:
    s = ParamSpec()
    std = 0.02
    s.add("tok_emb", (cfg.vocab, cfg.d_model), ("normal", std))
    s.add("pos_emb", (cfg.seq_len, cfg.d_model), ("normal", std))
    for i in range(cfg.n_layers):
        _block(s, f"l{i}", cfg.d_model, cfg.d_ff, std)
    s.add("ln_f", (cfg.d_model,), "ones")
    s.add("head_w", (cfg.d_model, cfg.vocab), ("normal", std))
    s.add("head_b", (cfg.vocab,), "zeros")
    return s


def vit_teacher_spec(cfg: ViTConfig) -> ParamSpec:
    s = ParamSpec()
    std = 0.02
    s.add("patch_w", (cfg.patch_dim, cfg.d_model), ("normal", std))
    s.add("patch_b", (cfg.d_model,), "zeros")
    s.add("pos_emb", (cfg.n_tokens, cfg.d_model), ("normal", std))
    for i in range(cfg.n_layers):
        _block(s, f"l{i}", cfg.d_model, cfg.d_ff, std)
    s.add("ln_f", (cfg.d_model,), "ones")
    # frozen AE decoder (the Fig. 7 eval head)
    s.add("dec_in_w", (cfg.d_model, cfg.dec_d_model), ("normal", std))
    s.add("dec_in_b", (cfg.dec_d_model,), "zeros")
    s.add("dec_pos", (cfg.n_tokens, cfg.dec_d_model), ("normal", std))
    for i in range(cfg.dec_layers):
        _block(s, f"d{i}", cfg.dec_d_model, cfg.dec_d_ff, std)
    s.add("dec_ln", (cfg.dec_d_model,), "ones")
    s.add("dec_out_w", (cfg.dec_d_model, cfg.patch_dim), ("normal", std))
    s.add("dec_out_b", (cfg.patch_dim,), "zeros")
    return s


def vlm_teacher_spec(cfg: VLMConfig) -> ParamSpec:
    s = ParamSpec()
    std = 0.02
    # vision tower
    s.add("v.patch_w", (cfg.patch_dim, cfg.v_d_model), ("normal", std))
    s.add("v.patch_b", (cfg.v_d_model,), "zeros")
    s.add("v.pos_emb", (cfg.n_img_tokens, cfg.v_d_model), ("normal", std))
    for i in range(cfg.v_layers):
        _block(s, f"v.l{i}", cfg.v_d_model, cfg.v_d_ff, std)
    s.add("v.ln_f", (cfg.v_d_model,), "ones")
    # projector (LLaVA's mm_projector)
    s.add("proj_w", (cfg.v_d_model, cfg.d_model), ("normal", std))
    s.add("proj_b", (cfg.d_model,), "zeros")
    # language decoder
    s.add("tok_emb", (cfg.vocab, cfg.d_model), ("normal", std))
    s.add("pos_emb", (cfg.seq_len, cfg.d_model), ("normal", std))
    for i in range(cfg.n_layers):
        _block(s, f"l{i}", cfg.d_model, cfg.d_ff, std)
    s.add("ln_f", (cfg.d_model,), "ones")
    s.add("head_w", (cfg.d_model, cfg.vocab), ("normal", std))
    s.add("head_b", (cfg.vocab,), "zeros")
    return s


# ---------------------------------------------------------------------------
# router (trainable) specs
# ---------------------------------------------------------------------------
#
# Init choices encode the paper's "start at the teacher" property:
#   * expert/head routers start at 0  ->  M*softmax(0) = uniform weight 1.0,
#     so k = M reproduces the teacher exactly (§4.1 normalization).
#   * token routers start with small weights and bias +1 -> sigmoid ~ 0.73,
#     every token selected at the 0.5 inference threshold from step one.
#   * LoRA B starts at 0 -> adapters are exact no-ops at init.

def lm_router_spec(cfg: LMConfig, lora_rank=None) -> ParamSpec:
    r = cfg.lora_rank if lora_rank is None else lora_rank
    s = ParamSpec()
    d, h, m = cfg.d_model, cfg.n_heads, cfg.n_experts
    for i in range(cfg.n_layers):
        s.add(f"l{i}.r_mha_in_w", (d,), ("normal", 0.02))
        s.add(f"l{i}.r_mha_in_b", (), ("const", 1.0))
        s.add(f"l{i}.r_mlp_in_w", (d,), ("normal", 0.02))
        s.add(f"l{i}.r_mlp_in_b", (), ("const", 1.0))
        s.add(f"l{i}.r_heads_w", (h, d), "zeros")
        s.add(f"l{i}.r_heads_b", (h,), "zeros")
        s.add(f"l{i}.r_experts_w", (m, d), "zeros")
        s.add(f"l{i}.r_experts_b", (m,), "zeros")
        if r > 0:
            s.add(f"l{i}.lora_q_a", (r, d), ("normal", 0.02))
            s.add(f"l{i}.lora_q_b", (d, r), "zeros")
            s.add(f"l{i}.lora_v_a", (r, d), ("normal", 0.02))
            s.add(f"l{i}.lora_v_b", (d, r), "zeros")
    return s


def vit_router_spec(cfg: ViTConfig, lora_rank=None) -> ParamSpec:
    lm_like = LMConfig(
        name=cfg.name, vocab=1, d_model=cfg.d_model, n_layers=cfg.n_layers,
        n_heads=cfg.n_heads, d_ff=cfg.d_ff, seq_len=cfg.n_tokens,
        n_experts=cfg.n_experts,
        lora_rank=cfg.lora_rank if lora_rank is None else lora_rank,
    )
    return lm_router_spec(lm_like)


def vlm_router_spec(cfg: VLMConfig, mlp_router: bool = False) -> ParamSpec:
    """Image-token selection router (Fig. 9): linear or 1-hidden-layer MLP."""
    s = ParamSpec()
    d = cfg.d_model
    if mlp_router:
        s.add("r_img_h_w", (d, cfg.router_hidden), ("normal", 0.02))
        s.add("r_img_h_b", (cfg.router_hidden,), "zeros")
        s.add("r_img_o_w", (cfg.router_hidden,), ("normal", 0.02))
        s.add("r_img_o_b", (), ("const", 1.0))
    else:
        s.add("r_img_w", (d,), ("normal", 0.02))
        s.add("r_img_b", (), ("const", 1.0))
    return s
