"""L2 — JAX model definitions: frozen teachers + ElastiFormer elastic
counterparts for all three modalities (LM / ViT / VLM).

All core functions operate on a single sequence ([T, D]); batch dimensions
are added with ``jax.vmap`` in ``train.py`` / ``aot.py``.  Parameters arrive
as flat f32 vectors (see params.py) so the Rust coordinator can own
checkpoints.

Routing semantics (paper §4 + Appendix B):
  * ``mode`` (runtime scalar): 0 = training top-k selection, 1 = inference
    0.5-threshold selection, 2 = bypass (input routers forced to identity —
    used for the capacity=1 equivalence oracle and the 1.0 serve tier).
  * ``caps`` (runtime f32[4]): [cap_mha_tokens, cap_mlp_tokens,
    frac_heads, frac_experts] — all fractions in (0, 1].
  * ``layer_en`` (runtime f32[L]): per-layer routing enable — 1 routed,
    0 dense teacher path (Fig. 7's even-layer experiment, and the
    "ElastiFormer on all layers" default).
"""

import functools

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref

EPS = 1e-6


def rmsnorm(x, w):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def _split_heads(x, n_heads):
    t, d = x.shape
    return x.reshape(t, n_heads, d // n_heads).transpose(1, 0, 2)  # [H,T,hd]


def _merge_heads(x):
    h, t, hd = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * hd)


def moefy(p, pre, n_experts):
    """Lossless MoE-fication of a dense MLP (paper §4.1, Fig. 3).

    W1 [D,F] is split column-wise into M blocks [M,D,F/M] (rows of the
    hidden layer), W2 [F,D] row-wise into [M,F/M,D]; the bias b2 stays
    shared.  Summing all blocks with weight 1 reproduces the dense MLP
    bit-for-bit.
    """
    w1, b1 = p[f"{pre}.mlp_w1"], p[f"{pre}.mlp_b1"]
    w2, b2 = p[f"{pre}.mlp_w2"], p[f"{pre}.mlp_b2"]
    d, f = w1.shape
    fm = f // n_experts
    w1b = w1.reshape(d, n_experts, fm).transpose(1, 0, 2)   # [M,D,Fm]
    b1b = b1.reshape(n_experts, fm)                          # [M,Fm]
    w2b = w2.reshape(n_experts, fm, d)                       # [M,Fm,D]
    return w1b, b1b, w2b, b2


def _attn(p, pre, xn, cfg, head_w, key_mask, causal, use_pallas, lora=None):
    """Shared attention body: projections (+LoRA), head-weighted attention,
    output projection.  head_w [T,H] already contains routing weight*mask."""
    q = xn @ p[f"{pre}.q_w"] + p[f"{pre}.q_b"]
    k = xn @ p[f"{pre}.k_w"] + p[f"{pre}.k_b"]
    v = xn @ p[f"{pre}.v_w"] + p[f"{pre}.v_b"]
    if lora is not None:
        qa, qb, va, vb = lora
        q = q + (xn @ qa.T) @ qb.T
        v = v + (xn @ va.T) @ vb.T
    qh, kh, vh = (_split_heads(t, cfg.n_heads) for t in (q, k, v))
    if use_pallas:
        out_h = kernels.masked_attention(qh, kh, vh, head_w, key_mask, causal)
    else:
        out_h = ref.masked_attention(qh, kh, vh, head_w, key_mask, causal)
    return _merge_heads(out_h) @ p[f"{pre}.o_w"] + p[f"{pre}.o_b"]


def _mlp_dense(p, pre, xn):
    h = ref.gelu(xn @ p[f"{pre}.mlp_w1"] + p[f"{pre}.mlp_b1"])
    return h @ p[f"{pre}.mlp_w2"] + p[f"{pre}.mlp_b2"]


# ---------------------------------------------------------------------------
# teacher (dense) path — with Fig. 2 structural-pruning hooks
# ---------------------------------------------------------------------------

def dense_block(p, pre, x, cfg, causal, head_mask, attn_on, mlp_on):
    """Teacher transformer block with optional structural pruning.

    head_mask [H] (1 keep / 0 prune), attn_on / mlp_on: scalars gating the
    whole residual branch (attn_on = mlp_on = 0 skips the layer entirely,
    Appendix A's 'skip transformer layer').
    """
    t = x.shape[0]
    xn = rmsnorm(x, p[f"{pre}.ln1"])
    head_w = jnp.broadcast_to(head_mask[None, :], (t, cfg.n_heads))
    attn_out = _attn(p, pre, xn, cfg, head_w, jnp.ones((t,), jnp.float32),
                     causal, use_pallas=False)
    x = x + attn_on * attn_out
    xn2 = rmsnorm(x, p[f"{pre}.ln2"])
    x = x + mlp_on * _mlp_dense(p, pre, xn2)
    return x


def lm_backbone_dense(p, cfg, tokens, head_mask, attn_on, mlp_on):
    """tokens [T] -> logits [T, V].  head_mask [L,H], attn_on/mlp_on [L]."""
    x = p["tok_emb"][tokens] + p["pos_emb"]
    for i in range(cfg.n_layers):
        x = dense_block(p, f"l{i}", x, cfg, True,
                        head_mask[i], attn_on[i], mlp_on[i])
    x = rmsnorm(x, p["ln_f"])
    return x @ p["head_w"] + p["head_b"]


# ---------------------------------------------------------------------------
# elastic path — the ElastiFormer contribution
# ---------------------------------------------------------------------------

def _token_gate(x, w, b, capacity, mode):
    """Input-subset-selection gate (Alg. 2 / B.1).

    Returns (gate [T], score [T], mask [T]): gate = mask * score during
    routing, identically 1.0 in bypass mode (mode == 2).
    """
    score = ref.token_router_scores(x, w, b)
    mask = ref.token_select_mask(score, capacity, jnp.minimum(mode, 1.0))
    maskf = mask.astype(jnp.float32)
    gate = jnp.where(mode > 1.5, jnp.ones_like(score), maskf * score)
    maskf = jnp.where(mode > 1.5, jnp.ones_like(maskf), maskf)
    return gate, score, maskf


def _param_gate(xn, w, b, frac, n_sub, use_pallas):
    """Parameter-subset-selection weights (Alg. 1): M*softmax -> top-k mask.

    Returns (wmask [T,M], raw_w [T,M], mask [T,M]).
    """
    raw = (kernels.fused_router if use_pallas else ref.fused_router)(xn, w, b)
    k = jnp.clip(jnp.round(frac * n_sub).astype(jnp.int32), 1, n_sub)
    mask = ref.topk_mask_lastdim(raw, k).astype(jnp.float32)
    return raw * mask, raw, mask


def elastic_block(p, r, pre, x, cfg, causal, caps, on, mode, use_pallas,
                  lora_rank):
    """One ElastiFormer transformer block.  Returns (x, stats dict).

    ``on`` in {0,1} (runtime): 0 = dense teacher path (all gates blended to
    identity), 1 = routed.  All four routers of Fig. 1 are applied here.
    """
    t = x.shape[0]
    cap_mha, cap_mlp, frac_h, frac_e = caps[0], caps[1], caps[2], caps[3]

    # --- input subset selection around MHA (routes on the block input) ---
    g_mha, s_mha, m_mha = _token_gate(
        x, r[f"{pre}.r_mha_in_w"], r[f"{pre}.r_mha_in_b"], cap_mha, mode)
    g_mha = on * g_mha + (1.0 - on)
    key_mask = on * m_mha + (1.0 - on)

    xn = rmsnorm(x, p[f"{pre}.ln1"])

    # --- parameter subset selection inside MHA (attention heads) ---
    hw, hraw, hmask = _param_gate(
        xn, r[f"{pre}.r_heads_w"], r[f"{pre}.r_heads_b"],
        frac_h, cfg.n_heads, use_pallas)
    head_w = on * hw + (1.0 - on)

    lora = None
    if lora_rank > 0:
        lora = (r[f"{pre}.lora_q_a"], r[f"{pre}.lora_q_b"],
                r[f"{pre}.lora_v_a"], r[f"{pre}.lora_v_b"])
    attn_out = _attn(p, pre, xn, cfg, head_w, key_mask, causal,
                     use_pallas, lora)
    x = x + g_mha[:, None] * attn_out

    # --- input subset selection around MLP ---
    g_mlp, s_mlp, m_mlp = _token_gate(
        x, r[f"{pre}.r_mlp_in_w"], r[f"{pre}.r_mlp_in_b"], cap_mlp, mode)
    g_mlp = on * g_mlp + (1.0 - on)

    xn2 = rmsnorm(x, p[f"{pre}.ln2"])

    # --- parameter subset selection inside MLP (MoE-fied experts) ---
    ew, eraw, emask = _param_gate(
        xn2, r[f"{pre}.r_experts_w"], r[f"{pre}.r_experts_b"],
        frac_e, cfg.n_experts, use_pallas)
    expert_wmask = on * ew + (1.0 - on)

    w1b, b1b, w2b, b2 = moefy(p, pre, cfg.n_experts)
    if use_pallas:
        y = kernels.routed_expert_mlp(xn2, w1b, b1b, w2b, b2, expert_wmask)
    else:
        y = ref.routed_expert_mlp(xn2, w1b, b1b, w2b, b2, expert_wmask)
    x = x + g_mlp[:, None] * y

    stats = {
        "s_mha": s_mha, "m_mha": m_mha,          # [T]
        "s_mlp": s_mlp, "m_mlp": m_mlp,          # [T]
        "head_w": hraw, "head_mask": hmask,      # [T,H]
        "expert_w": eraw, "expert_mask": emask,  # [T,M]
    }
    return x, stats


def _stack_stats(per_layer):
    return {k: jnp.stack([s[k] for s in per_layer]) for k in per_layer[0]}


def lm_backbone_elastic(p, r, cfg, tokens, caps, layer_en, mode,
                        use_pallas=None, lora_rank=None):
    """tokens [T] -> (logits [T,V], stats {k: [L,...]})."""
    use_pallas = cfg.use_pallas if use_pallas is None else use_pallas
    lora_rank = cfg.lora_rank if lora_rank is None else lora_rank
    x = p["tok_emb"][tokens] + p["pos_emb"]
    per_layer = []
    for i in range(cfg.n_layers):
        x, st = elastic_block(p, r, f"l{i}", x, cfg, True, caps,
                              layer_en[i], mode, use_pallas, lora_rank)
        per_layer.append(st)
    x = rmsnorm(x, p["ln_f"])
    return x @ p["head_w"] + p["head_b"], _stack_stats(per_layer)


# ---------------------------------------------------------------------------
# ViT (encoder + frozen AE decoder)
# ---------------------------------------------------------------------------

def patchify(img_flat, cfg):
    """[H*W*C] -> [N, patch*patch*C] non-overlapping patches."""
    hw = cfg.img_size
    pch = cfg.patch
    img = img_flat.reshape(hw, hw, cfg.channels)
    n = hw // pch
    x = img.reshape(n, pch, n, pch, cfg.channels)
    return x.transpose(0, 2, 1, 3, 4).reshape(n * n, pch * pch * cfg.channels)


def vit_encode_dense(p, cfg, img_flat, head_mask, attn_on, mlp_on):
    """img [H*W*C] -> encoder tokens [N, D] (with Fig.2-style prune hooks)."""
    x = patchify(img_flat, cfg) @ p["patch_w"] + p["patch_b"] + p["pos_emb"]
    for i in range(cfg.n_layers):
        x = dense_block(p, f"l{i}", x, cfg, False,
                        head_mask[i], attn_on[i], mlp_on[i])
    return rmsnorm(x, p["ln_f"])


def vit_encode_elastic(p, r, cfg, img_flat, caps, layer_en, mode,
                       use_pallas=None):
    use_pallas = cfg.use_pallas if use_pallas is None else use_pallas
    x = patchify(img_flat, cfg) @ p["patch_w"] + p["patch_b"] + p["pos_emb"]
    per_layer = []
    for i in range(cfg.n_layers):
        x, st = elastic_block(p, r, f"l{i}", x, cfg, False, caps,
                              layer_en[i], mode, use_pallas, cfg.lora_rank)
        per_layer.append(st)
    return rmsnorm(x, p["ln_f"]), _stack_stats(per_layer)


def vit_decode(p, cfg, enc_tokens):
    """Frozen AE decoder: encoder tokens [N,D] -> reconstructed patches
    [N, patch_dim].  (The Fig. 7 metric compares decoder outputs.)"""
    x = enc_tokens @ p["dec_in_w"] + p["dec_in_b"] + p["dec_pos"]
    ones_h = jnp.ones((cfg.dec_heads,), jnp.float32)
    dec_cfg = _DecCfg(cfg.dec_heads)
    for i in range(cfg.dec_layers):
        x = dense_block(p, f"d{i}", x, dec_cfg, False, ones_h, 1.0, 1.0)
    x = rmsnorm(x, p["dec_ln"])
    return x @ p["dec_out_w"] + p["dec_out_b"]


class _DecCfg:
    def __init__(self, n_heads):
        self.n_heads = n_heads


# ---------------------------------------------------------------------------
# VLM (vision tower -> projector -> language decoder with image prefix)
# ---------------------------------------------------------------------------

def _vlm_vision_cfg(cfg):
    class _V:
        n_heads = cfg.v_heads
    return _V()


def vlm_image_tokens(p, cfg, img_flat):
    """Vision tower + projector: img -> [N_img, D_lm] decoder-ready tokens."""
    x = patchify_v(img_flat, cfg) @ p["v.patch_w"] + p["v.patch_b"] + p["v.pos_emb"]
    vcfg = _vlm_vision_cfg(cfg)
    ones_h = jnp.ones((cfg.v_heads,), jnp.float32)
    for i in range(cfg.v_layers):
        x = dense_block(p, f"v.l{i}", x, vcfg, False, ones_h, 1.0, 1.0)
    x = rmsnorm(x, p["v.ln_f"])
    return x @ p["proj_w"] + p["proj_b"]


def patchify_v(img_flat, cfg):
    hw, pch = cfg.img_size, cfg.patch
    img = img_flat.reshape(hw, hw, cfg.channels)
    n = hw // pch
    x = img.reshape(n, pch, n, pch, cfg.channels)
    return x.transpose(0, 2, 1, 3, 4).reshape(n * n, pch * pch * cfg.channels)


def vlm_img_router_scores(r, img_tokens, mlp_router):
    """Scalar score per image token (linear or 1-hidden-GELU-MLP router)."""
    if mlp_router:
        h = ref.gelu(img_tokens @ r["r_img_h_w"] + r["r_img_h_b"])
        return jax.nn.sigmoid(h @ r["r_img_o_w"] + r["r_img_o_b"])
    return jax.nn.sigmoid(img_tokens @ r["r_img_w"] + r["r_img_b"])


def vlm_decode(p, cfg, img_tokens, img_gate, img_keymask, text_tokens):
    """Language decoder over [selected image prefix; text tokens].

    img_gate [N_img] scales the embeddings of selected image tokens (routing
    weight, gradient path); img_keymask removes dropped image tokens from
    attention.  Returns logits [T_total, V].
    """
    n_img = cfg.n_img_tokens
    emb_txt = p["tok_emb"][text_tokens]
    x = jnp.concatenate([img_tokens * img_gate[:, None], emb_txt], axis=0)
    x = x + p["pos_emb"]
    t_total = x.shape[0]
    key_mask = jnp.concatenate(
        [img_keymask, jnp.ones((cfg.text_len,), jnp.float32)], axis=0)
    ones_h = jnp.ones((cfg.n_heads,), jnp.float32)
    head_w = jnp.broadcast_to(ones_h[None, :], (t_total, cfg.n_heads))
    for i in range(cfg.n_layers):
        pre = f"l{i}"
        xn = rmsnorm(x, p[f"{pre}.ln1"])
        attn_out = _attn(p, pre, xn, cfg, head_w, key_mask, True,
                         use_pallas=False)
        # dropped image tokens contribute nothing downstream
        x = x + key_mask[:, None] * attn_out
        xn2 = rmsnorm(x, p[f"{pre}.ln2"])
        x = x + key_mask[:, None] * _mlp_dense(p, pre, xn2)
    x = rmsnorm(x, p["ln_f"])
    return x @ p["head_w"] + p["head_b"]


def vlm_forward(p, r, cfg, img_flat, text_tokens, capacity, mode, mlp_router):
    """Full Elasti-VLM forward for one (image, caption) pair.

    Returns (text_logits [text_len, V], img_scores [N_img], img_mask [N_img]).
    mode semantics match the LM path; capacity is the image-token fraction.
    """
    img_tok = vlm_image_tokens(p, cfg, img_flat)
    scores = vlm_img_router_scores(r, img_tok, mlp_router) if r is not None \
        else jnp.ones((cfg.n_img_tokens,), jnp.float32)
    if r is None:
        gate = jnp.ones_like(scores)
        maskf = jnp.ones_like(scores)
    else:
        mask = ref.token_select_mask(scores, capacity, jnp.minimum(mode, 1.0))
        maskf = mask.astype(jnp.float32)
        gate = jnp.where(mode > 1.5, jnp.ones_like(scores), maskf * scores)
        maskf = jnp.where(mode > 1.5, jnp.ones_like(maskf), maskf)
    logits = vlm_decode(p, cfg, img_tok, gate, maskf, text_tokens)
    return logits[cfg.n_img_tokens:], scores, maskf
