"""L1 Pallas kernel: routed (MoE-fied) expert MLP.

The paper's parameter-subset-selection hot spot: each token is processed by
only the top-k of M expert blocks obtained by losslessly splitting the dense
MLP (W1 row-wise, W2 column-wise).  The kernel computes

    y[t] = sum_m wmask[t, m] * ( gelu(x[t] @ w1[m] + b1[m]) @ w2[m] ) + b2

over a grid of (token-tile, expert).  Experts are the innermost grid
dimension so each expert's weight block is staged exactly once per token
tile and the output tile accumulates in place across the expert loop.

TPU mapping (DESIGN.md §Hardware-Adaptation): on a real TPU the BlockSpec
index map stages one expert block (D x Fm and Fm x D) from HBM into VMEM per
grid step — the analogue of the paper's per-expert CUDA dispatch — and the
token tile stays VMEM-resident across the expert loop (double-buffered
weight fetch).  With D, Fm multiples of 128 every matmul maps onto full MXU
tiles; a de-selected expert (wmask column all-zero for the tile) would be
skipped at the grid level by Mosaic.  Here we run interpret=True (CPU PJRT
cannot execute Mosaic custom-calls) so the savings are analytic, not
wall-clock — see analysis::flops on the Rust side.

VMEM per grid step = TILE_T*D (x) + D*Fm + Fm (w1,b1) + Fm*D (w2)
                   + TILE_T*Fm (h) + TILE_T*D (acc), all f32.
For lm_base (D=256, Fm=128, TILE_T=64): ~0.46 MB — comfortably under the
~16 MB/core budget; lm_large (D=512, Fm=128): ~0.85 MB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_T = 64


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, wm_ref, o_ref):
    m_idx = pl.program_id(1)
    x = x_ref[...]              # [Tt, D]
    w1 = w1_ref[0]              # [D, Fm]  (expert block picked by BlockSpec)
    b1 = b1_ref[0]              # [Fm]
    w2 = w2_ref[0]              # [Fm, D]
    wm = wm_ref[...][:, 0]      # [Tt]     (this expert's wmask column)

    h = ref.gelu(x @ w1 + b1[None, :])        # [Tt, Fm]
    y = (h @ w2) * wm[:, None]                # [Tt, D]

    @pl.when(m_idx == 0)
    def _init():
        o_ref[...] = y + b2_ref[...][None, :]

    @pl.when(m_idx > 0)
    def _acc():
        o_ref[...] += y


@jax.custom_vjp
def routed_expert_mlp(x, w1, b1, w2, b2, wmask):
    """Pallas forward, exact jnp-reference backward (see ref.py).

    Shapes match ref.routed_expert_mlp:
      x [T,D], w1 [M,D,Fm], b1 [M,Fm], w2 [M,Fm,D], b2 [D], wmask [T,M].
    """
    t, d = x.shape
    m, _, fm = w1.shape
    tile_t = min(TILE_T, t)
    grid = (pl.cdiv(t, tile_t), m)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda i, j: (i, 0)),       # x tile
            pl.BlockSpec((1, d, fm), lambda i, j: (j, 0, 0)),     # w1[m]
            pl.BlockSpec((1, fm), lambda i, j: (j, 0)),           # b1[m]
            pl.BlockSpec((1, fm, d), lambda i, j: (j, 0, 0)),     # w2[m]
            pl.BlockSpec((d,), lambda i, j: (0,)),                # b2
            pl.BlockSpec((tile_t, 1), lambda i, j: (i, j)),       # wmask col
        ],
        out_specs=pl.BlockSpec((tile_t, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2, wmask)


def _fwd(x, w1, b1, w2, b2, wmask):
    y = routed_expert_mlp(x, w1, b1, w2, b2, wmask)
    return y, (x, w1, b1, w2, b2, wmask)


def _bwd(res, g):
    _, vjp = jax.vjp(ref.routed_expert_mlp, *res)
    return vjp(g)


routed_expert_mlp.defvjp(_fwd, _bwd)


def macs(t, d, fm, m_active):
    """Analytic MACs with m_active experts per token (up + down proj)."""
    return 2 * t * d * fm * m_active
