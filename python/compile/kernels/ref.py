"""Pure-jnp reference implementations (correctness oracles) for the L1
Pallas kernels.

Every Pallas kernel in this package is checked against the function of the
same name here (pytest + hypothesis, see ``python/tests``), and the
``custom_vjp`` backward of each kernel *is* the jax-derived VJP of these
references — so the AOT training artifacts get exact gradients while the
forward pass exercises the Pallas code path.
"""

import jax
import jax.numpy as jnp


def gelu(x):
    """tanh-approximation GELU (matches jax.nn.gelu(approximate=True))."""
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# routed expert MLP (parameter subset selection inside the MLP, paper §4.1)
# ---------------------------------------------------------------------------

def routed_expert_mlp(x, w1, b1, w2, b2, wmask):
    """MoE-fied MLP forward with combined routing weight*mask.

    Args:
      x:     [T, D]     tokens.
      w1:    [M, D, Fm] expert up-projection blocks (row-split of dense W1).
      b1:    [M, Fm]    expert up bias blocks.
      w2:    [M, Fm, D] expert down-projection blocks (col-split of dense W2).
      b2:    [D]        shared down bias (applied once, not per expert).
      wmask: [T, M]     routing_weight * selection_mask per (token, expert).

    Returns: [T, D] = sum_m wmask[t,m] * (gelu(x @ w1[m] + b1[m]) @ w2[m]) + b2

    With wmask == 1 everywhere this equals the dense MLP exactly (the
    paper's lossless MoE-fication identity) because the dense forward is
    the block-sum:  W2 @ sigma(W1 x) = sum_m W2_m @ sigma(W1_m x).
    """
    # h: [M, T, Fm]
    h = gelu(jnp.einsum("td,mdf->mtf", x, w1) + b1[:, None, :])
    # y_m: [M, T, D]
    y_m = jnp.einsum("mtf,mfd->mtd", h, w2)
    y = jnp.einsum("mtd,tm->td", y_m, wmask)
    return y + b2[None, :]


# ---------------------------------------------------------------------------
# head-masked multi-head attention (parameter subset selection inside MHA)
# ---------------------------------------------------------------------------

def masked_attention(q, k, v, head_w, key_mask, causal):
    """Multi-head attention with per-(token, head) output weights and a
    per-token key mask (used by input-subset selection around MHA: tokens
    dropped from the block neither query nor serve as keys).

    Args:
      q, k, v:  [H, T, Hd]
      head_w:   [T, H]  routing_weight * mask per (query token, head);
                zero rows disable a head for that token (output only —
                compute cost accounting is analytic, see analysis::flops).
      key_mask: [T]     1.0 for tokens visible as keys, 0.0 for dropped.
      causal:   bool (static) — causal LM vs bidirectional ViT.

    Returns: [H, T, Hd] per-head outputs, already scaled by head_w.
    """
    hd = q.shape[-1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(hd))
    t = q.shape[1]
    neg = jnp.float32(-1e30)
    mask = key_mask[None, None, :] > 0.5
    if causal:
        tri = jnp.tril(jnp.ones((t, t), dtype=bool))
        mask = jnp.logical_and(mask, tri[None, :, :])
    scores = jnp.where(mask, scores, neg)
    # A fully-masked row (query token dropped + causal row 0) would produce
    # NaNs; guard by always letting a token attend to itself.
    eye = jnp.eye(t, dtype=bool)[None, :, :]
    scores = jnp.where(eye, jnp.maximum(scores, -1e29), scores)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hts,hsd->htd", attn, v)
    return out * head_w.T[:, :, None]


# ---------------------------------------------------------------------------
# fused router (linear -> M * softmax, paper Alg. 1 line 1)
# ---------------------------------------------------------------------------

def fused_router(x, wr, br):
    """Routing weights for parameter subset selection.

    Args:
      x:  [T, D] tokens.
      wr: [M, D] router weight.
      br: [M]    router bias.

    Returns: [T, M] = M * softmax(x @ wr.T + br, axis=-1).

    The M* normalization makes k == M with uniform logits reproduce the
    unrouted network exactly (paper §4.1).
    """
    m = wr.shape[0]
    logits = x @ wr.T + br[None, :]
    return jnp.float32(m) * jax.nn.softmax(logits, axis=-1)


# ---------------------------------------------------------------------------
# shared (non-kernel) routing math used by both L2 model paths
# ---------------------------------------------------------------------------

def topk_mask_lastdim(scores, k):
    """Boolean mask of the top-k entries along the last dim.

    ``k`` may be a traced scalar (runtime capacity): the mask is computed by
    rank comparison, so shapes stay static and a single lowered artifact
    serves every capacity in a sweep.  Ranks are derived from pairwise
    comparisons (O(n^2) over the last dim, n <= seq_len here) instead of
    argsort-of-argsort: comparison ranking has no gather/scatter in its
    (transposed) graph, which keeps the vmap+grad lowering compatible with
    the xla_extension 0.5.1 runtime the Rust side executes on.  Ties break
    toward the lower index, matching a stable descending sort.
    """
    s_i = scores[..., :, None]
    s_j = scores[..., None, :]
    n = scores.shape[-1]
    idx = jnp.arange(n)
    earlier = idx[None, :] < idx[:, None]  # [n, n]: j strictly before i
    beats = (s_j > s_i) | ((s_j == s_i) & earlier)
    ranks = jnp.sum(beats.astype(jnp.int32), axis=-1)
    return ranks < k


def token_router_scores(x, w, b):
    """Scalar sigmoid score per token (input subset selection, paper B.1).

    x: [T, D]; w: [D]; b: []  ->  [T] in (0, 1).
    """
    return jax.nn.sigmoid(x @ w + b)


def token_select_mask(scores, capacity, mode):
    """Selection mask for input subset selection.

    mode == 0 (training): top-k with k = ceil(capacity * T)   (paper Alg. 2)
    mode == 1 (inference): threshold score > 0.5               (paper B.1)

    ``capacity`` and ``mode`` are runtime scalars.
    """
    t = scores.shape[-1]
    k = jnp.ceil(capacity * t).astype(jnp.int32)
    topk = topk_mask_lastdim(scores, k)
    thresh = scores > 0.5
    return jnp.where(mode > 0.5, thresh, topk)
