"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles.

Import surface used by the L2 model:

    from compile import kernels
    kernels.routed_expert_mlp(...)   # Pallas fwd / exact-ref bwd
    kernels.masked_attention(...)
    kernels.fused_router(...)
    kernels.ref                      # the jnp oracles + shared routing math
"""

from . import ref  # noqa: F401
from .routed_expert_mlp import routed_expert_mlp  # noqa: F401
from .masked_attention import masked_attention  # noqa: F401
from .fused_router import fused_router  # noqa: F401
