"""L1 Pallas kernel: head-masked multi-head attention.

Implements the attention side of ElastiFormer's two selection schemes:
  * parameter subset selection *inside* MHA — per-(token, head) routing
    weights ``head_w`` scale each head's output (zero = head skipped);
  * input subset selection *around* MHA — ``key_mask`` removes dropped
    tokens from the key set (they ride the residual stream instead).

Grid: (head, query-tile).  Each grid step loads one head's q-tile plus that
head's full K/V panel and runs a masked softmax-attention tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's H100 kernel would
assign heads to thread blocks; here each head is a grid row, so a head whose
``head_w`` column is all-zero for the tile is a grid row Mosaic can prune —
the TPU analogue of not launching the block.  The q-tile x K panel matmuls
run on the MXU (Hd=32..64 pads to the 128 lane; TPU-targeted configs use
Hd=128).  For seq lens beyond a few K the K/V panel would be tiled with an
online-softmax carry in VMEM scratch; at the repro's T<=128 the whole panel
fits (~0.1 MB/head), so we keep the single-panel schedule, which is also
what flash-attn collapses to at this size.

VMEM per grid step (f32): Tt*Hd (q) + 2*T*Hd (k,v) + Tt*T (scores)
  lm_base (T=128, Hd=32, Tt=64): ~0.1 MB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_Q = 64


def _kernel(q_ref, k_ref, v_ref, hw_ref, km_ref, o_ref, *, causal, tile_q):
    i = pl.program_id(1)            # query-tile index
    q = q_ref[0]                    # [Tt, Hd]
    k = k_ref[0]                    # [T, Hd]
    v = v_ref[0]                    # [T, Hd]
    hw = hw_ref[...][:, 0]          # [Tt]  this head's routing weight column
    km = km_ref[...]                # [T]

    hd = q.shape[-1]
    t = k.shape[0]
    scores = (q @ k.T) / jnp.sqrt(jnp.float32(hd))     # [Tt, T]

    rows = i * tile_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = km[None, :] > 0.5
    if causal:
        mask = jnp.logical_and(mask, cols <= rows)
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    # Self-attention guard: a fully-masked row would NaN the softmax.
    scores = jnp.where(cols == rows, jnp.maximum(scores, -1e29), scores)

    attn = jax.nn.softmax(scores, axis=-1)
    o_ref[0] = (attn @ v) * hw[:, None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def masked_attention(q, k, v, head_w, key_mask, causal):
    """Pallas forward, jnp-reference backward.  See ref.masked_attention.

    q, k, v: [H, T, Hd]; head_w: [T, H]; key_mask: [T]; causal: static bool.
    Returns [H, T, Hd] (per-head outputs scaled by head_w).
    """
    return _forward(q, k, v, head_w, key_mask, causal)


def _forward(q, k, v, head_w, key_mask, causal):
    h, t, hd = q.shape
    tile_q = min(TILE_Q, t)
    grid = (h, pl.cdiv(t, tile_q))
    kern = functools.partial(_kernel, causal=causal, tile_q=tile_q)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, hd), lambda h_, i: (h_, i, 0)),  # q
            pl.BlockSpec((1, t, hd), lambda h_, i: (h_, 0, 0)),       # k
            pl.BlockSpec((1, t, hd), lambda h_, i: (h_, 0, 0)),       # v
            pl.BlockSpec((tile_q, 1), lambda h_, i: (i, h_)),         # head_w
            pl.BlockSpec((t,), lambda h_, i: (0,)),                   # key_mask
        ],
        out_specs=pl.BlockSpec((1, tile_q, hd), lambda h_, i: (h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, hd), q.dtype),
        interpret=True,
    )(q, k, v, head_w, key_mask)


def _fwd(q, k, v, head_w, key_mask, causal):
    y = masked_attention(q, k, v, head_w, key_mask, causal)
    return y, (q, k, v, head_w, key_mask)


def _bwd(causal, res, g):
    q, k, v, head_w, key_mask = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_, hw_, km_: ref.masked_attention(q_, k_, v_, hw_, km_, causal),
        q, k, v, head_w, key_mask,
    )
    return vjp(g)


masked_attention.defvjp(_fwd, _bwd)


def macs(t, hd, h_active):
    """Analytic MACs for h_active heads: QK^T + AV."""
    return 2 * t * t * hd * h_active
