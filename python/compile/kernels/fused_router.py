"""L1 Pallas kernel: fused parameter-subset router (paper Alg. 1, line 1).

Computes ``M * softmax(x @ Wr^T + br)`` in a single VMEM-resident pass per
token tile — the small matmul, the row-softmax and the M* renormalization
(which makes k == M reproduce the unrouted network exactly) are fused so the
[T, M] logits never round-trip through HBM.

TPU mapping: the router matmul is tiny (D x M, M = 8..32); it rides the
same q-tile VMEM residency as the surrounding block, so on TPU the router
costs one MXU pass over a thin panel plus VPU softmax — negligible next to
the expert blocks it gates, which is exactly the paper's "as low as .00006%
additional parameters" premise.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_T = 64


def _kernel(x_ref, wr_ref, br_ref, o_ref):
    x = x_ref[...]          # [Tt, D]
    wr = wr_ref[...]        # [M, D]
    br = br_ref[...]        # [M]
    m = wr.shape[0]
    logits = x @ wr.T + br[None, :]
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    o_ref[...] = jnp.float32(m) * e / jnp.sum(e, axis=-1, keepdims=True)


@jax.custom_vjp
def fused_router(x, wr, br):
    """Pallas forward, jnp-reference backward.  See ref.fused_router.

    x: [T, D]; wr: [M, D]; br: [M]  ->  [T, M].
    """
    t, d = x.shape
    m = wr.shape[0]
    tile_t = min(TILE_T, t)
    return pl.pallas_call(
        _kernel,
        grid=(pl.cdiv(t, tile_t),),
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_t, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m), x.dtype),
        interpret=True,
    )(x, wr, br)


def _fwd(x, wr, br):
    return fused_router(x, wr, br), (x, wr, br)


def _bwd(res, g):
    _, vjp = jax.vjp(ref.fused_router, *res)
    return vjp(g)


fused_router.defvjp(_fwd, _bwd)
