"""L2 — training-step definitions lowered to AOT artifacts.

Everything stateful lives in flat f32 vectors (params / Adam m / Adam v) so
the Rust coordinator can keep them device-resident across steps and
checkpoint them byte-for-byte.  The learning rate arrives as a runtime
scalar — the cosine/warmup schedule is computed by the Rust trainer.

Step functions:
  * ``pretrain_step``      — full-model AdamW on next-token CE (teacher).
  * ``distill_step``       — ElastiFormer: AdamW on *router (+LoRA)* params
    only, objective Eq.(1): L_distill + L_load + L_topk.
  * ``vit_pretrain_step``  — autoencoder reconstruction (teacher ViT).
  * ``vit_distill_step``   — cosine-distance distillation (Elasti-ViT).
  * ``vlm_pretrain_step``  — caption CE given image prefix (teacher VLM).
  * ``vlm_distill_step``   — top-k forward KL on text logits (Elasti-VLM).
"""

import functools

import jax
import jax.numpy as jnp

from . import losses, model
from .kernels import ref

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01
GRAD_CLIP = 1.0


def adamw_update(g, p, m, v, step, lr, weight_decay=WEIGHT_DECAY):
    """One AdamW step on flat vectors, with global-norm gradient clipping."""
    gnorm = jnp.sqrt(jnp.sum(g * g) + 1e-12)
    g = g * jnp.minimum(1.0, GRAD_CLIP / gnorm)
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mhat = m2 / (1.0 - ADAM_B1 ** t)
    vhat = v2 / (1.0 - ADAM_B2 ** t)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + weight_decay * p)
    return p2, m2, v2, gnorm


# ---------------------------------------------------------------------------
# causal LM
# ---------------------------------------------------------------------------

def _lm_dense_logits_batch(spec, cfg, flat, tokens, head_mask, attn_on, mlp_on):
    p = spec.unflatten(flat)
    fn = lambda tok: model.lm_backbone_dense(p, cfg, tok, head_mask,
                                             attn_on, mlp_on)
    return jax.vmap(fn)(tokens)


def _lm_ce(spec, cfg, flat, tokens, head_mask, attn_on, mlp_on):
    logits = _lm_dense_logits_batch(spec, cfg, flat, tokens,
                                    head_mask, attn_on, mlp_on)
    return losses.cross_entropy(logits[:, :-1], tokens[:, 1:]), logits


def lm_pretrain_step(spec, cfg, flat, m, v, step, lr, tokens):
    """Returns (flat', m', v', [loss, gnorm])."""
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    full_l = jnp.ones((cfg.n_layers,), jnp.float32)

    def loss_fn(f):
        ce, _ = _lm_ce(spec, cfg, f, tokens, full_h, full_l, full_l)
        return ce

    loss, g = jax.value_and_grad(loss_fn)(flat)
    p2, m2, v2, gnorm = adamw_update(g, flat, m, v, step, lr)
    return p2, m2, v2, jnp.stack([loss, gnorm])


def lm_teacher_forward(spec, cfg, flat, tokens, head_mask, attn_on, mlp_on):
    """Fig. 2 pruning probe: logits + CE under structural masks."""
    ce, logits = _lm_ce(spec, cfg, flat, tokens, head_mask, attn_on, mlp_on)
    return logits, ce


def _lm_elastic_logits_batch(tspec, rspec, cfg, tflat, rflat, tokens, caps,
                             layer_en, mode, use_pallas, lora_rank):
    p = tspec.unflatten(tflat)
    r = rspec.unflatten(rflat)
    fn = lambda tok: model.lm_backbone_elastic(
        p, r, cfg, tok, caps, layer_en, mode, use_pallas, lora_rank)
    return jax.vmap(fn)(tokens)  # (logits [B,T,V], stats {k: [B,L,...]})


def lm_elastic_forward(tspec, rspec, cfg, tflat, rflat, tokens, caps,
                       layer_en, mode, use_pallas=None, lora_rank=None):
    """The request-path artifact.  Returns
    (logits, ce, s_mha [B,L,T], s_mlp [B,L,T], m_mha, m_mlp,
     head_w [B,L,T,H], expert_w [B,L,T,M])."""
    logits, st = _lm_elastic_logits_batch(
        tspec, rspec, cfg, tflat, rflat, tokens, caps, layer_en, mode,
        use_pallas, lora_rank)
    ce = losses.cross_entropy(logits[:, :-1], tokens[:, 1:])
    return (logits, ce, st["s_mha"], st["s_mlp"], st["m_mha"], st["m_mlp"],
            st["head_w"], st["expert_w"])


def _router_aux(st):
    """Load-balance (heads + experts) and top-k BCE (both token routers)."""
    load = losses.load_balance(st["head_w"], st["head_mask"] > 0.5) \
        + losses.load_balance(st["expert_w"], st["expert_mask"] > 0.5)
    bce = losses.topk_bce(st["s_mha"], st["m_mha"] > 0.5) \
        + losses.topk_bce(st["s_mlp"], st["m_mlp"] > 0.5)
    return load, bce


def lm_distill_step(tspec, rspec, cfg, teacher_flat, student_flat, rflat,
                    m, v, step, lr, tokens, caps, layer_en, temp,
                    loss_type="fwd_topk", lora_rank=None, use_pallas=False):
    """Self-distillation step (Eq. 1).  Trains the router vector only.

    ``student_flat`` is the frozen backbone the routers steer — identical to
    ``teacher_flat`` in the paper's main experiments, a noised copy in the
    Fig. 4 ablation.  Returns (rflat', m', v',
    metrics [distill, load, bce, total, student_ce, teacher_ce, gnorm, frac_tokens]).
    """
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    full_l = jnp.ones((cfg.n_layers,), jnp.float32)
    t_logits = _lm_dense_logits_batch(
        tspec, cfg, teacher_flat, tokens, full_h, full_l, full_l)
    t_logits = jax.lax.stop_gradient(t_logits)

    def loss_fn(rf):
        logits, st = _lm_elastic_logits_batch(
            tspec, rspec, cfg, student_flat, rf, tokens, caps, layer_en,
            jnp.float32(0.0), use_pallas, lora_rank)
        dl = losses.distill_loss(t_logits, logits, temp, loss_type,
                                 cfg.distill_topk)
        load, bce = _router_aux(st)
        total = dl + load + bce
        ce = losses.cross_entropy(logits[:, :-1], tokens[:, 1:])
        frac = jnp.mean(st["m_mlp"])
        return total, (dl, load, bce, ce, frac)

    (total, (dl, load, bce, ce, frac)), g = \
        jax.value_and_grad(loss_fn, has_aux=True)(rflat)
    r2, m2, v2, gnorm = adamw_update(g, rflat, m, v, step, lr,
                                     weight_decay=0.0)
    t_ce = losses.cross_entropy(t_logits[:, :-1], tokens[:, 1:])
    metrics = jnp.stack([dl, load, bce, total, ce, t_ce, gnorm, frac])
    return r2, m2, v2, metrics


def lm_serve_forward(tspec, rspec, cfg, tflat, rflat, tokens, capacity):
    """Static-capacity serving artifact (one per tier, see configs.SERVE_TIERS).

    Unlike ``lm_elastic_forward`` (runtime capacity, mask-based — uniform
    compute), this path bakes k = ceil(capacity * T) **statically** and
    physically gathers the selected tokens before the MLP, so the dominant
    MLP FLOPs really shrink by (1 - capacity) on any backend.  Heads/experts
    use the same fraction via masking.  capacity == 1.0 lowers to the exact
    teacher (bypass mode).

    Returns logits [B, T, V].
    """
    p = tspec.unflatten(tflat)
    r = rspec.unflatten(rflat)
    t = cfg.seq_len
    k_tok = max(1, int(round(capacity * t)))
    k_head = max(1, int(round(capacity * cfg.n_heads)))
    k_exp = max(1, int(round(capacity * cfg.n_experts)))
    bypass = capacity >= 1.0

    def ranks_desc(s):
        """Pairwise-comparison descending ranks (no sort/top_k HLO ops —
        see losses.kl_topk for the runtime-compat rationale)."""
        n = s.shape[-1]
        idx = jnp.arange(n)
        earlier = idx[None, :] < idx[:, None]
        beats = (s[None, :] > s[:, None]) | \
            ((s[None, :] == s[:, None]) & earlier)
        return jnp.sum(beats.astype(jnp.int32), axis=-1)

    def one_seq(tok):
        x = p["tok_emb"][tok] + p["pos_emb"]
        for i in range(cfg.n_layers):
            pre = f"l{i}"
            # --- MHA: mask-based token selection (keys must stay aligned) ---
            if bypass:
                g_mha = jnp.ones((t,), jnp.float32)
                key_mask = jnp.ones((t,), jnp.float32)
            else:
                s = ref.token_router_scores(
                    x, r[f"{pre}.r_mha_in_w"], r[f"{pre}.r_mha_in_b"])
                key_mask = (ranks_desc(s) < k_tok).astype(jnp.float32)
                g_mha = key_mask * s
            xn = rmsnorm_(x, p[f"{pre}.ln1"])
            if bypass:
                head_w = jnp.ones((t, cfg.n_heads), jnp.float32)
            else:
                raw = ref.fused_router(
                    xn, r[f"{pre}.r_heads_w"], r[f"{pre}.r_heads_b"])
                hm = ref.topk_mask_lastdim(raw, k_head).astype(jnp.float32)
                head_w = raw * hm
            attn_out = model._attn(p, pre, xn, cfg, head_w, key_mask, True,
                                   use_pallas=False)
            x = x + g_mha[:, None] * attn_out

            # --- MLP: physical compaction of the top-k tokens ---
            xn2 = rmsnorm_(x, p[f"{pre}.ln2"])
            if bypass:
                x = x + model._mlp_dense(p, pre, xn2)
            else:
                s2 = ref.token_router_scores(
                    x, r[f"{pre}.r_mlp_in_w"], r[f"{pre}.r_mlp_in_b"])
                # selection matrix sel[j, t] = 1 iff token t has rank j < k;
                # sel @ x compacts the selected rows into [k, D] (one thin
                # matmul instead of a batched gather, which the 0.5.1
                # runtime cannot parse), and sel.T scatters them back.
                rk = ranks_desc(s2)
                sel = (rk[None, :] == jnp.arange(k_tok)[:, None]) \
                    .astype(jnp.float32)                       # [k, T]
                x_sel = sel @ xn2                              # [k, D]
                s_sel = sel @ s2                               # [k]
                if k_exp >= cfg.n_experts:
                    y_sel = model._mlp_dense(p, pre, x_sel)
                else:
                    raw_e = ref.fused_router(
                        x_sel, r[f"{pre}.r_experts_w"], r[f"{pre}.r_experts_b"])
                    em = ref.topk_mask_lastdim(raw_e, k_exp).astype(jnp.float32)
                    w1b, b1b, w2b, b2 = model.moefy(p, pre, cfg.n_experts)
                    y_sel = ref.routed_expert_mlp(x_sel, w1b, b1b, w2b, b2,
                                                  raw_e * em)
                x = x + sel.T @ (s_sel[:, None] * y_sel)
        x = rmsnorm_(x, p["ln_f"])
        return x @ p["head_w"] + p["head_b"]

    return jax.vmap(one_seq)(tokens)


def rmsnorm_(x, w):
    return model.rmsnorm(x, w)


# ---------------------------------------------------------------------------
# ViT
# ---------------------------------------------------------------------------

def _vit_dense_batch(spec, cfg, flat, imgs, head_mask, attn_on, mlp_on):
    p = spec.unflatten(flat)
    enc = jax.vmap(lambda im: model.vit_encode_dense(
        p, cfg, im, head_mask, attn_on, mlp_on))(imgs)
    dec = jax.vmap(lambda e: model.vit_decode(p, cfg, e))(enc)
    return enc, dec


def vit_pretrain_step(spec, cfg, flat, m, v, step, lr, imgs):
    """Autoencoder pretraining of the ViT teacher (recon MSE on patches)."""
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    full_l = jnp.ones((cfg.n_layers,), jnp.float32)

    def loss_fn(f):
        _, dec = _vit_dense_batch(spec, cfg, f, imgs, full_h, full_l, full_l)
        target = jax.vmap(lambda im: model.patchify(im, cfg))(imgs)
        return jnp.mean((dec - target) ** 2)

    loss, g = jax.value_and_grad(loss_fn)(flat)
    p2, m2, v2, gnorm = adamw_update(g, flat, m, v, step, lr)
    return p2, m2, v2, jnp.stack([loss, gnorm])


def vit_teacher_forward(spec, cfg, flat, imgs):
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    full_l = jnp.ones((cfg.n_layers,), jnp.float32)
    enc, dec = _vit_dense_batch(spec, cfg, flat, imgs, full_h, full_l, full_l)
    return enc, dec


def vit_elastic_forward(tspec, rspec, cfg, tflat, rflat, imgs, caps,
                        layer_en, mode, use_pallas=None):
    """Returns (enc_student, dec_student, dec_teacher, cos_sim [B],
    s_mlp [B,L,N], m_mlp, head_w, expert_w).

    cos_sim is the Fig. 7 metric: cosine similarity between the frozen
    decoder's outputs on student vs teacher encodings.
    """
    p = tspec.unflatten(tflat)
    r = rspec.unflatten(rflat)
    enc_s, st = jax.vmap(lambda im: model.vit_encode_elastic(
        p, r, cfg, im, caps, layer_en, mode, use_pallas))(imgs)
    dec_s = jax.vmap(lambda e: model.vit_decode(p, cfg, e))(enc_s)
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    full_l = jnp.ones((cfg.n_layers,), jnp.float32)
    enc_t, dec_t = _vit_dense_batch(tspec, cfg, tflat, imgs,
                                    full_h, full_l, full_l)
    cos = losses.cosine_similarity(dec_s, dec_t)
    return (enc_s, dec_s, dec_t, cos, st["s_mlp"], st["m_mlp"],
            st["head_w"], st["expert_w"])


def vit_distill_step(tspec, rspec, cfg, tflat, rflat, m, v, step, lr, imgs,
                     caps, layer_en, use_pallas=False):
    """Cosine-distance self-distillation of the Elasti-ViT encoder.

    Returns (rflat', m', v', metrics [distill, load, bce, total, cos_enc, gnorm,
    frac_tokens, 0]).
    """
    p_t = tspec.unflatten(tflat)
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads), jnp.float32)
    full_l = jnp.ones((cfg.n_layers,), jnp.float32)
    enc_t = jax.vmap(lambda im: model.vit_encode_dense(
        p_t, cfg, im, full_h, full_l, full_l))(imgs)
    enc_t = jax.lax.stop_gradient(enc_t)

    def loss_fn(rf):
        r = rspec.unflatten(rf)
        enc_s, st = jax.vmap(lambda im: model.vit_encode_elastic(
            p_t, r, cfg, im, caps, layer_en, jnp.float32(0.0),
            use_pallas))(imgs)
        dl = losses.cosine_distance(enc_s, enc_t)
        load, bce = _router_aux(st)
        total = dl + load + bce
        cos = jnp.mean(losses.cosine_similarity(enc_s, enc_t))
        frac = jnp.mean(st["m_mlp"])
        return total, (dl, load, bce, cos, frac)

    (total, (dl, load, bce, cos, frac)), g = \
        jax.value_and_grad(loss_fn, has_aux=True)(rflat)
    r2, m2, v2, gnorm = adamw_update(g, rflat, m, v, step, lr,
                                     weight_decay=0.0)
    metrics = jnp.stack([dl, load, bce, total, cos, gnorm, frac,
                         jnp.float32(0.0)])
    return r2, m2, v2, metrics


# ---------------------------------------------------------------------------
# VLM
# ---------------------------------------------------------------------------

def _vlm_logits_batch(tspec, rspec, cfg, tflat, rflat, imgs, texts,
                      capacity, mode, mlp_router):
    p = tspec.unflatten(tflat)
    r = rspec.unflatten(rflat) if rspec is not None else None
    fn = lambda im, tx: model.vlm_forward(p, r, cfg, im, tx, capacity, mode,
                                          mlp_router)
    return jax.vmap(fn)(imgs, texts)


def vlm_pretrain_step(spec, cfg, flat, m, v, step, lr, imgs, texts):
    """Caption CE given the image prefix (trains the whole VLM teacher)."""

    def loss_fn(f):
        logits, _, _ = _vlm_logits_batch(
            spec, None, cfg, f, None, imgs, texts,
            jnp.float32(1.0), jnp.float32(2.0), False)
        return losses.cross_entropy(logits[:, :-1], texts[:, 1:])

    loss, g = jax.value_and_grad(loss_fn)(flat)
    p2, m2, v2, gnorm = adamw_update(g, flat, m, v, step, lr)
    return p2, m2, v2, jnp.stack([loss, gnorm])


def vlm_teacher_forward(spec, cfg, flat, imgs, texts):
    logits, _, _ = _vlm_logits_batch(
        spec, None, cfg, flat, None, imgs, texts,
        jnp.float32(1.0), jnp.float32(2.0), False)
    ce = losses.cross_entropy(logits[:, :-1], texts[:, 1:])
    return logits, ce


def vlm_elastic_forward(tspec, rspec, cfg, tflat, rflat, imgs, texts,
                        capacity, mode, mlp_router):
    """Returns (text_logits, ce, img_scores [B,N_img], img_mask [B,N_img])."""
    logits, scores, mask = _vlm_logits_batch(
        tspec, rspec, cfg, tflat, rflat, imgs, texts, capacity, mode,
        mlp_router)
    ce = losses.cross_entropy(logits[:, :-1], texts[:, 1:])
    return logits, ce, scores, mask


def vlm_distill_step(tspec, rspec, cfg, tflat, rflat, m, v, step, lr, imgs,
                     texts, capacity, temp, mlp_router):
    """Top-k forward-KL distillation of image-token routing (Fig. 9).

    Returns (rflat', m', v', metrics [distill, bce, total, student_ce,
    teacher_ce, gnorm, frac_img_tokens, 0]).
    """
    t_logits, _, _ = _vlm_logits_batch(
        tspec, None, cfg, tflat, None, imgs, texts,
        jnp.float32(1.0), jnp.float32(2.0), False)
    t_logits = jax.lax.stop_gradient(t_logits)

    def loss_fn(rf):
        logits, scores, mask = _vlm_logits_batch(
            tspec, rspec, cfg, tflat, rf, imgs, texts, capacity,
            jnp.float32(0.0), mlp_router)
        dl = losses.distill_loss(t_logits, logits, temp, "fwd_topk", 32)
        bce = losses.topk_bce(scores, mask > 0.5)
        total = dl + bce
        ce = losses.cross_entropy(logits[:, :-1], texts[:, 1:])
        frac = jnp.mean(mask)
        return total, (dl, bce, ce, frac)

    (total, (dl, bce, ce, frac)), g = \
        jax.value_and_grad(loss_fn, has_aux=True)(rflat)
    r2, m2, v2, gnorm = adamw_update(g, rflat, m, v, step, lr,
                                     weight_decay=0.0)
    t_ce = losses.cross_entropy(t_logits[:, :-1], texts[:, 1:])
    metrics = jnp.stack([dl, bce, total, ce, t_ce, gnorm, frac,
                         jnp.float32(0.0)])
    return r2, m2, v2, metrics
