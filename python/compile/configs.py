"""Model / artifact configurations for the ElastiFormer reproduction.

Each config fully determines the static shapes of every AOT artifact that
``aot.py`` lowers for it.  The Rust coordinator reads these values back from
``artifacts/<name>/manifest.json`` — nothing here is duplicated by hand on
the Rust side.

Sizing notes (CPU sandbox, see DESIGN.md §2):
  * ``lm_tiny``  — used by pytest and cargo test; sub-second steps.
  * ``lm_base``  — the end-to-end example model (~6.5M params).
  * ``lm_large`` — paper-scale-ish option (~29M params with V=256); the
    e2e driver accepts ``--config lm_large`` but defaults to lm_base so the
    recorded run fits the sandbox budget.
  * ``vit_tiny`` / ``vlm_tiny`` — Elasti-ViT / Elasti-VLM substrates.
"""

from dataclasses import dataclass, field, asdict
from typing import Optional


@dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer (GPT-style, RMSNorm pre-norm, GELU MLP)."""

    name: str = "lm_tiny"
    kind: str = "lm"  # lm | vit | vlm
    vocab: int = 256  # byte-level tokenizer (0 = pad, 1 = BOS, 2 = EOS)
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 128
    batch: int = 8
    # ElastiFormer routing
    n_experts: int = 8       # MoE-fication of the dense MLP (d_ff % n_experts == 0)
    lora_rank: int = 8       # rank of the optional LoRA(q,v) adapters (0 = none)
    distill_topk: int = 32   # top-k bucket size of the forward-KL distillation loss
    # Pallas
    use_pallas: bool = True  # route the MLP/attention hot paths through L1 kernels

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_expert(self) -> int:
        assert self.d_ff % self.n_experts == 0
        return self.d_ff // self.n_experts

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["d_expert"] = self.d_expert
        return d


@dataclass(frozen=True)
class ViTConfig:
    """ViT encoder + small frozen autoencoder decoder (MAE-style eval head).

    Images are ``img_size x img_size x channels`` procedural textures from
    the Rust ``data::imagen`` generator; patches of ``patch x patch`` give
    ``(img_size/patch)**2`` tokens.
    """

    name: str = "vit_tiny"
    kind: str = "vit"
    img_size: int = 32
    patch: int = 4
    channels: int = 3
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    batch: int = 8
    # decoder (frozen at distill time; used for the Fig. 7 eval metric)
    dec_d_model: int = 64
    dec_layers: int = 2
    dec_heads: int = 4
    dec_d_ff: int = 256
    n_experts: int = 8
    lora_rank: int = 0
    use_pallas: bool = True

    @property
    def n_tokens(self) -> int:
        assert self.img_size % self.patch == 0
        return (self.img_size // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_expert(self) -> int:
        return self.d_ff // self.n_experts

    def to_dict(self) -> dict:
        d = asdict(self)
        d["n_tokens"] = self.n_tokens
        d["patch_dim"] = self.patch_dim
        d["head_dim"] = self.head_dim
        d["d_expert"] = self.d_expert
        d["seq_len"] = self.n_tokens
        return d


@dataclass(frozen=True)
class VLMConfig:
    """LLaVA-shaped VLM: ViT encoder -> linear projector -> LM decoder.

    The decoder consumes ``n_img_tokens`` projected image tokens followed by
    ``text_len`` caption tokens; Elasti-VLM's router selects the top-k image
    tokens that reach the decoder (Fig. 1 mid-bottom / Fig. 9).
    """

    name: str = "vlm_tiny"
    kind: str = "vlm"
    # vision tower
    img_size: int = 32
    patch: int = 4
    channels: int = 3
    v_d_model: int = 128
    v_layers: int = 3
    v_heads: int = 4
    v_d_ff: int = 512
    # language decoder
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    text_len: int = 48
    batch: int = 8
    # image-token router: "linear" always lowered; "mlp" variant too (Fig. 9)
    router_hidden: int = 128
    use_pallas: bool = True

    @property
    def n_img_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def seq_len(self) -> int:
        return self.n_img_tokens + self.text_len

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    def to_dict(self) -> dict:
        d = asdict(self)
        d["n_img_tokens"] = self.n_img_tokens
        d["seq_len"] = self.seq_len
        d["patch_dim"] = self.patch_dim
        return d


LM_TINY = LMConfig()
LM_BASE = LMConfig(
    name="lm_base", d_model=256, n_layers=8, n_heads=8, d_ff=1024,
    seq_len=128, batch=8, n_experts=8, lora_rank=8,
)
LM_LARGE = LMConfig(
    name="lm_large", d_model=512, n_layers=10, n_heads=8, d_ff=2048,
    seq_len=128, batch=4, n_experts=16, lora_rank=8,
)
VIT_TINY = ViTConfig()
VLM_TINY = VLMConfig()

# Configs lowered by ``make artifacts``.  lm_large is lowered on demand only
# (python -m compile.aot --config lm_large) to keep artifact builds fast.
DEFAULT_BUILD = [LM_TINY, LM_BASE, VIT_TINY, VLM_TINY]

BY_NAME = {c.name: c for c in [LM_TINY, LM_BASE, LM_LARGE, VIT_TINY, VLM_TINY]}

# Static capacity tiers baked into the gather-compressed *serve* artifacts
# (real wall-clock savings; the sweep artifacts use runtime capacities).
SERVE_TIERS = [0.25, 0.5, 0.75, 1.0]

# Static distillation-loss variants lowered for the Fig. 4 ablation.
FIG4_LOSSES = ["fwd_topk", "fwd_full", "rev_topk", "rev_full"]
