"""Unit tests for the distillation + auxiliary objectives (paper §4.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import losses

jax.config.update("jax_platform_name", "cpu")


def _logits(seed, shape):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape),
                       jnp.float32)


class TestKL:
    def test_identical_logits_zero(self):
        l = _logits(0, (4, 16))
        for fn in (lambda: losses.kl_full(l, l, jnp.float32(1.0)),
                   lambda: losses.kl_full(l, l, jnp.float32(1.0), True),
                   lambda: losses.kl_topk(l, l, jnp.float32(1.0), 5),
                   lambda: losses.kl_topk(l, l, jnp.float32(1.0), 5, True)):
            assert abs(float(fn())) < 1e-5

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 15))
    def test_kl_nonnegative(self, seed, k):
        a = _logits(seed, (3, 16))
        b = _logits(seed + 1, (3, 16))
        assert float(losses.kl_full(a, b, jnp.float32(1.0))) >= -1e-6
        assert float(losses.kl_topk(a, b, jnp.float32(1.0), k)) >= -1e-6

    def test_topk_ignores_tail_differences(self):
        """Perturbing far-below-top-k logits barely moves the top-k loss."""
        a = _logits(2, (2, 32)) * 5.0
        b = a + 0.1
        base = float(losses.kl_topk(a, b, jnp.float32(1.0), 4))
        # push the smallest logits around
        idx = jnp.argsort(a, axis=-1)[:, :8]
        b2 = b.at[jnp.arange(2)[:, None], idx].add(-3.0)
        moved = float(losses.kl_topk(a, b2, jnp.float32(1.0), 4))
        full_moved = float(losses.kl_full(a, b2, jnp.float32(1.0)))
        assert abs(moved - base) < 0.3 * abs(full_moved - base) + 1e-4

    def test_temperature_softens(self):
        a = _logits(3, (2, 16)) * 4.0
        b = _logits(4, (2, 16)) * 4.0
        hot = float(losses.kl_full(a, b, jnp.float32(4.0)))
        cold = float(losses.kl_full(a, b, jnp.float32(1.0)))
        assert hot < cold

    def test_forward_reverse_differ(self):
        a = _logits(5, (2, 16))
        b = _logits(6, (2, 16))
        f = float(losses.kl_full(a, b, jnp.float32(1.0)))
        r = float(losses.kl_full(a, b, jnp.float32(1.0), reverse=True))
        assert abs(f - r) > 1e-4


class TestCosine:
    def test_identical_zero_distance(self):
        x = _logits(0, (3, 8, 16))
        assert abs(float(losses.cosine_distance(x, x))) < 1e-6
        np.testing.assert_allclose(np.asarray(losses.cosine_similarity(x, x)),
                                   1.0, atol=1e-6)

    def test_opposite_distance_two(self):
        x = _logits(1, (4, 16))
        assert abs(float(losses.cosine_distance(x, -x)) - 2.0) < 1e-5

    def test_scale_invariance(self):
        x = _logits(2, (4, 16))
        y = _logits(3, (4, 16))
        d1 = float(losses.cosine_distance(x, y))
        d2 = float(losses.cosine_distance(3.0 * x, 0.5 * y))
        assert abs(d1 - d2) < 1e-5


class TestAux:
    def test_load_balance_uniform_is_minimum(self):
        m, t = 8, 64
        w_uni = jnp.ones((t, m), jnp.float32)
        mask_uni = jnp.zeros((t, m), bool).at[:, :4].set(True)
        l_uni = float(losses.load_balance(w_uni, mask_uni))
        # concentrated routing: everything to expert 0
        w_conc = jnp.zeros((t, m), jnp.float32).at[:, 0].set(float(m))
        mask_conc = jnp.zeros((t, m), bool).at[:, 0].set(True)
        l_conc = float(losses.load_balance(w_conc, mask_conc))
        assert l_uni < l_conc

    def test_topk_bce_perfect_scores(self):
        mask = jnp.asarray([True, False, True, False])
        good = jnp.asarray([0.999, 0.001, 0.999, 0.001], jnp.float32)
        bad = jnp.asarray([0.001, 0.999, 0.001, 0.999], jnp.float32)
        assert float(losses.topk_bce(good, mask)) < 0.01
        assert float(losses.topk_bce(bad, mask)) > 2.0

    def test_cross_entropy_ignores_pad(self):
        logits = _logits(0, (2, 6, 10))
        tgt = jnp.asarray([[3, 4, 5, 0, 0, 0], [6, 7, 8, 9, 0, 0]], jnp.int32)
        ce = float(losses.cross_entropy(logits, tgt))
        # changing logits at pad positions must not change the loss
        logits2 = logits.at[:, 3:, :].add(5.0)
        logits2 = logits2.at[1, 4:, :].add(-2.0)
        ce2 = float(losses.cross_entropy(
            logits2.at[:, :3, :].set(logits[:, :3, :])
                   .at[1, 3, :].set(logits[1, 3, :]), tgt))
        assert abs(ce - ce2) < 1e-5

    def test_top1_match_bounds(self):
        a = _logits(1, (2, 5, 7))
        tgt = jnp.full((2, 5), 3, jnp.int32)
        assert abs(float(losses.top1_match(a, a, tgt)) - 1.0) < 1e-6
        b = -a
        assert float(losses.top1_match(a, b, tgt)) <= 1.0


class TestTopKMaskEquivalence:
    """The mask-based kl_topk (HLO-0.5.1-compatible) must equal the
    canonical gather-based top-k KL formulation."""

    def _gather_kl_topk(self, a, b, temp, k, reverse=False):
        pt = jax.nn.softmax(a / temp, axis=-1)
        ps = jax.nn.softmax(b / temp, axis=-1)
        topv, topi = jax.lax.top_k(pt, k)
        ps_top = jnp.take_along_axis(ps, topi, axis=-1)
        rt = jnp.clip(1.0 - jnp.sum(topv, axis=-1, keepdims=True), 1e-8, 1.0)
        rs = jnp.clip(1.0 - jnp.sum(ps_top, axis=-1, keepdims=True), 1e-8, 1.0)
        pt_b = jnp.clip(jnp.concatenate([topv, rt], -1), 1e-8, 1.0)
        ps_b = jnp.clip(jnp.concatenate([ps_top, rs], -1), 1e-8, 1.0)
        if reverse:
            pt_b, ps_b = ps_b, pt_b
        return jnp.mean(jnp.sum(pt_b * (jnp.log(pt_b) - jnp.log(ps_b)), -1))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 12),
           reverse=st.booleans())
    def test_matches_gather_formulation(self, seed, k, reverse):
        a = _logits(seed, (3, 24)) * 2.0
        b = _logits(seed + 1, (3, 24)) * 2.0
        ours = float(losses.kl_topk(a, b, jnp.float32(1.0), k, reverse))
        ref = float(self._gather_kl_topk(a, b, jnp.float32(1.0), k, reverse))
        # ties in pt can enlarge the mask bucket; with continuous random
        # logits ties have measure zero, so the two must agree tightly
        assert abs(ours - ref) < 1e-4, (ours, ref)
