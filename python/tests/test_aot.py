"""AOT manifest + artifact invariants.

Guards the contract between ``aot.py`` and the Rust runtime: every entry in
the manifest must name an existing HLO-text file whose parameter count and
shapes agree with the declared arg specs, and the flat param tables must be
contiguous and gap-free.
"""

import json
import os

import numpy as np
import pytest

from compile import configs, params

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest(name):
    path = os.path.join(ART, name, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"artifacts for {name} not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def _check_table_contiguous(table):
    off = 0
    for e in table:
        assert e["offset"] == off, f"{e['name']} offset gap"
        size = int(np.prod(e["shape"])) if e["shape"] else 1
        assert e["size"] == size
        off += size
    return off


@pytest.mark.parametrize("name", ["lm_tiny", "lm_base", "vit_tiny", "vlm_tiny"])
class TestManifest:
    def test_entries_have_files_and_parameter_counts(self, name):
        man = _manifest(name)
        cfg_dir = os.path.join(ART, name)
        for ename, e in man["entries"].items():
            path = os.path.join(cfg_dir, e["file"])
            assert os.path.exists(path), f"{ename}: missing {e['file']}"
            text = open(path).read(4000)
            assert text.startswith("HloModule"), f"{ename}: not HLO text"
            assert len(e["outputs"]) >= 1

    def test_param_tables_contiguous(self, name):
        man = _manifest(name)
        total = _check_table_contiguous(man["teacher_params"])
        assert total > 0
        for table in man["router_params"].values():
            _check_table_contiguous(table)

    def test_hlo_entry_param_count_matches_args(self, name):
        """The HLO ENTRY must declare exactly len(args) parameters."""
        man = _manifest(name)
        cfg_dir = os.path.join(ART, name)
        for ename, e in man["entries"].items():
            text = open(os.path.join(cfg_dir, e["file"])).read()
            entry = text.split("ENTRY")[1]
            header = entry.split("->")[0]
            n_params = header.count("parameter(")
            if n_params == 0:  # parameters appear in the body for some styles
                n_params = text.count(" = f32[")  # fallback, not used in practice
            assert n_params == len(e["args"]), \
                f"{ename}: {n_params} HLO params vs {len(e['args'])} manifest args"


def test_manifest_matches_python_spec_lm_tiny():
    man = _manifest("lm_tiny")
    cfg = configs.LM_TINY
    tspec = params.lm_teacher_spec(cfg)
    assert man["teacher_params"][-1]["offset"] + \
        man["teacher_params"][-1]["size"] == tspec.total
    names = [e["name"] for e in man["teacher_params"]]
    assert names == [n for n, _, _ in tspec.entries]
    for r in (0, 1, cfg.lora_rank):
        rspec = params.lm_router_spec(cfg, lora_rank=r)
        tab = man["router_params"][str(r)]
        assert tab[-1]["offset"] + tab[-1]["size"] == rspec.total


def test_router_param_budget_is_tiny():
    """Table 1's premise: routing params are a vanishing fraction of the
    teacher (< 3% even for the tiny configs; the paper reports <= 0.25%
    at real scale — the ratio shrinks with D and L)."""
    man = _manifest("lm_tiny")
    teacher = man["teacher_params"][-1]["offset"] + \
        man["teacher_params"][-1]["size"]
    router0 = man["router_params"]["0"]
    r_total = router0[-1]["offset"] + router0[-1]["size"]
    assert r_total / teacher < 0.03
