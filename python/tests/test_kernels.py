"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps).

This is the CORE correctness signal for the kernel layer: every kernel must
match its ``ref.py`` oracle across shapes/dtypes/capacities, including
ragged (non-tile-divisible) sequence lengths, and the custom_vjp backward
must equal the jax-derived gradient of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5
RTOL = 2e-5


def _rng(seed):
    return np.random.default_rng(seed)


def _allclose(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# routed_expert_mlp
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 150),
    d=st.sampled_from([8, 32, 64]),
    m=st.sampled_from([1, 2, 4, 8]),
    fm=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_routed_expert_mlp_matches_ref(t, d, m, fm, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
    w1 = jnp.asarray(0.2 * r.normal(size=(m, d, fm)), jnp.float32)
    b1 = jnp.asarray(0.2 * r.normal(size=(m, fm)), jnp.float32)
    w2 = jnp.asarray(0.2 * r.normal(size=(m, fm, d)), jnp.float32)
    b2 = jnp.asarray(0.2 * r.normal(size=(d,)), jnp.float32)
    wm = jnp.asarray(r.uniform(size=(t, m)), jnp.float32)
    _allclose(kernels.routed_expert_mlp(x, w1, b1, w2, b2, wm),
              ref.routed_expert_mlp(x, w1, b1, w2, b2, wm))


def test_routed_expert_mlp_zero_mask_is_bias_only():
    r = _rng(0)
    t, d, m, fm = 33, 16, 4, 8
    x = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
    w1 = jnp.asarray(r.normal(size=(m, d, fm)), jnp.float32)
    b1 = jnp.asarray(r.normal(size=(m, fm)), jnp.float32)
    w2 = jnp.asarray(r.normal(size=(m, fm, d)), jnp.float32)
    b2 = jnp.asarray(r.normal(size=(d,)), jnp.float32)
    wm = jnp.zeros((t, m), jnp.float32)
    y = kernels.routed_expert_mlp(x, w1, b1, w2, b2, wm)
    _allclose(y, jnp.broadcast_to(b2, (t, d)))


def test_routed_expert_mlp_moefication_lossless():
    """Block-split MoE with all-ones mask == the dense MLP (paper §4.1)."""
    r = _rng(1)
    t, d, f, m = 40, 24, 48, 4
    fm = f // m
    x = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
    w1d = jnp.asarray(0.3 * r.normal(size=(d, f)), jnp.float32)
    b1d = jnp.asarray(0.3 * r.normal(size=(f,)), jnp.float32)
    w2d = jnp.asarray(0.3 * r.normal(size=(f, d)), jnp.float32)
    b2d = jnp.asarray(0.3 * r.normal(size=(d,)), jnp.float32)
    dense = ref.gelu(x @ w1d + b1d) @ w2d + b2d
    w1 = w1d.reshape(d, m, fm).transpose(1, 0, 2)
    b1 = b1d.reshape(m, fm)
    w2 = w2d.reshape(m, fm, d)
    wm = jnp.ones((t, m), jnp.float32)
    _allclose(kernels.routed_expert_mlp(x, w1, b1, w2, b2d, wm), dense,
              atol=1e-4, rtol=1e-4)


def test_routed_expert_mlp_grads_match_ref():
    r = _rng(2)
    t, d, m, fm = 20, 12, 4, 8
    args = [
        jnp.asarray(0.3 * r.normal(size=s), jnp.float32)
        for s in [(t, d), (m, d, fm), (m, fm), (m, fm, d), (d,), (t, m)]
    ]

    def loss_k(*a):
        return jnp.sum(jnp.sin(kernels.routed_expert_mlp(*a)))

    def loss_r(*a):
        return jnp.sum(jnp.sin(ref.routed_expert_mlp(*a)))

    gk = jax.grad(loss_k, argnums=tuple(range(6)))(*args)
    gr = jax.grad(loss_r, argnums=tuple(range(6)))(*args)
    for a, b in zip(gk, gr):
        _allclose(a, b, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# masked_attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(2, 130),
    h=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_attention_matches_ref(t, h, hd, causal, seed):
    r = _rng(seed)
    q = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    hw = jnp.asarray(r.uniform(size=(t, h)), jnp.float32)
    km = jnp.asarray((r.uniform(size=(t,)) > 0.3).astype("f4"))
    _allclose(kernels.masked_attention(q, k, v, hw, km, causal),
              ref.masked_attention(q, k, v, hw, km, causal))


def test_masked_attention_zero_head_w_zeroes_output():
    r = _rng(3)
    h, t, hd = 2, 17, 8
    q, k, v = (jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
               for _ in range(3))
    hw = jnp.zeros((t, h), jnp.float32)
    km = jnp.ones((t,), jnp.float32)
    out = kernels.masked_attention(q, k, v, hw, km, True)
    _allclose(out, jnp.zeros_like(out))


def test_masked_attention_key_mask_blocks_information():
    """Output for token t must not depend on the values of masked keys."""
    r = _rng(4)
    h, t, hd = 2, 12, 8
    q = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    hw = jnp.ones((t, h), jnp.float32)
    km = jnp.ones((t,), jnp.float32).at[5].set(0.0)
    out1 = kernels.masked_attention(q, k, v, hw, km, True)
    v2 = v.at[:, 5, :].set(99.0)
    k2 = k.at[:, 5, :].set(-99.0)
    out2 = kernels.masked_attention(q, k2, v2, hw, km, True)
    # every row except 5 itself (the self-attention NaN guard keeps the
    # diagonal live) must be identical
    keep = np.asarray([i for i in range(t) if i != 5])
    _allclose(out1[:, keep], out2[:, keep])


def test_masked_attention_causality():
    r = _rng(5)
    h, t, hd = 2, 16, 8
    q = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(h, t, hd)), jnp.float32)
    hw = jnp.ones((t, h), jnp.float32)
    km = jnp.ones((t,), jnp.float32)
    out1 = kernels.masked_attention(q, k, v, hw, km, True)
    # perturb the future: rows < 8 must not change
    k2 = k.at[:, 12:, :].set(7.0)
    v2 = v.at[:, 12:, :].set(-7.0)
    out2 = kernels.masked_attention(q, k2, v2, hw, km, True)
    _allclose(out1[:, :8], out2[:, :8])


def test_masked_attention_grads_match_ref():
    r = _rng(6)
    h, t, hd = 2, 10, 4
    q, k, v = (jnp.asarray(0.5 * r.normal(size=(h, t, hd)), jnp.float32)
               for _ in range(3))
    hw = jnp.asarray(r.uniform(size=(t, h)), jnp.float32)
    km = jnp.ones((t,), jnp.float32)

    gk = jax.grad(lambda *a: jnp.sum(
        jnp.tanh(kernels.masked_attention(*a, km, True))), argnums=(0, 1, 2, 3))(q, k, v, hw)
    gr = jax.grad(lambda *a: jnp.sum(
        jnp.tanh(ref.masked_attention(*a, km, True))), argnums=(0, 1, 2, 3))(q, k, v, hw)
    for a, b in zip(gk, gr):
        _allclose(a, b, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused_router
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 140),
    d=st.sampled_from([8, 32, 64]),
    m=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_router_matches_ref(t, d, m, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
    wr = jnp.asarray(0.5 * r.normal(size=(m, d)), jnp.float32)
    br = jnp.asarray(0.5 * r.normal(size=(m,)), jnp.float32)
    _allclose(kernels.fused_router(x, wr, br), ref.fused_router(x, wr, br))


def test_fused_router_rows_sum_to_m():
    r = _rng(7)
    t, d, m = 37, 16, 8
    x = jnp.asarray(r.normal(size=(t, d)), jnp.float32)
    wr = jnp.asarray(r.normal(size=(m, d)), jnp.float32)
    br = jnp.asarray(r.normal(size=(m,)), jnp.float32)
    w = kernels.fused_router(x, wr, br)
    _allclose(jnp.sum(w, axis=-1), jnp.full((t,), float(m)))


def test_fused_router_zero_weights_give_uniform_ones():
    """The paper's identity-at-init property: W_r = 0 -> all weights 1."""
    t, d, m = 11, 8, 4
    x = jnp.asarray(_rng(8).normal(size=(t, d)), jnp.float32)
    w = kernels.fused_router(x, jnp.zeros((m, d)), jnp.zeros((m,)))
    _allclose(w, jnp.ones((t, m)))


# ---------------------------------------------------------------------------
# shared routing math
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 64),
    k=st.integers(0, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_topk_mask_selects_exactly_min_k_n(n, k, seed):
    s = jnp.asarray(_rng(seed).normal(size=(n,)), jnp.float32)
    mask = ref.topk_mask_lastdim(s, jnp.int32(k))
    assert int(mask.sum()) == min(max(k, 0), n)
    # the selected set dominates the unselected set
    if 0 < k < n:
        sel = np.asarray(s)[np.asarray(mask)]
        uns = np.asarray(s)[~np.asarray(mask)]
        assert sel.min() >= uns.max() - 1e-6


def test_topk_mask_matches_argsort_semantics():
    s = jnp.asarray([0.3, 0.9, 0.1, 0.9, 0.5], jnp.float32)
    mask = ref.topk_mask_lastdim(s, jnp.int32(3))
    # ties break toward the lower index: {1, 3, 4}
    assert list(np.asarray(mask)) == [False, True, False, True, True]


def test_token_select_mask_modes():
    s = jnp.asarray([0.9, 0.2, 0.6, 0.4], jnp.float32)
    topk = ref.token_select_mask(s, jnp.float32(0.5), jnp.float32(0.0))
    assert list(np.asarray(topk)) == [True, False, True, False]
    thr = ref.token_select_mask(s, jnp.float32(0.5), jnp.float32(1.0))
    assert list(np.asarray(thr)) == [True, False, True, False]
    thr2 = ref.token_select_mask(jnp.asarray([0.4, 0.2]), jnp.float32(1.0),
                                 jnp.float32(1.0))
    assert list(np.asarray(thr2)) == [False, False]
