"""L2 correctness: elastic-forward invariants across all three modalities.

The central oracle is the paper's §4.1 equivalence property: with bypass
mode, capacity 1 and zero-initialized parameter routers, the elastic model
IS the teacher.  We additionally check layer_en blending, LoRA no-op at
init, routing monotonicity, and the Fig. 2 pruning hooks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, params, train

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lm():
    cfg = configs.LMConfig(name="lm_test", d_model=32, n_layers=2, n_heads=2,
                           d_ff=64, seq_len=24, batch=2, n_experts=4,
                           lora_rank=2, distill_topk=8)
    tspec = params.lm_teacher_spec(cfg)
    rspec = params.lm_router_spec(cfg)
    P = tspec.init_flat(jax.random.PRNGKey(0))
    R = rspec.init_flat(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2),
                                (cfg.batch, cfg.seq_len), 3, cfg.vocab)
    return cfg, tspec, rspec, P, R, tokens


def _teacher_logits(lm_fix):
    cfg, tspec, _, P, _, tokens = lm_fix
    full_h = jnp.ones((cfg.n_layers, cfg.n_heads))
    full_l = jnp.ones((cfg.n_layers,))
    logits, _ = train.lm_teacher_forward(tspec, cfg, P, tokens,
                                         full_h, full_l, full_l)
    return logits


CAPS1 = jnp.asarray([1.0, 1.0, 1.0, 1.0], jnp.float32)


class TestEquivalence:
    def test_bypass_mode_equals_teacher(self, lm):
        cfg, tspec, rspec, P, R, tokens = lm
        lt = _teacher_logits(lm)
        full_l = jnp.ones((cfg.n_layers,))
        for pallas in (False, True):
            out = train.lm_elastic_forward(
                tspec, rspec, cfg, P, R, tokens, CAPS1, full_l,
                jnp.float32(2.0), use_pallas=pallas)
            np.testing.assert_allclose(np.asarray(out[0]), np.asarray(lt),
                                       atol=2e-5, rtol=2e-5)

    def test_all_layers_disabled_equals_teacher_any_capacity(self, lm):
        cfg, tspec, rspec, P, R, tokens = lm
        lt = _teacher_logits(lm)
        zeros_l = jnp.zeros((cfg.n_layers,))
        caps = jnp.asarray([0.3, 0.3, 0.5, 0.25], jnp.float32)
        out = train.lm_elastic_forward(
            tspec, rspec, cfg, P, R, tokens, caps, zeros_l,
            jnp.float32(0.0), use_pallas=False)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(lt),
                                   atol=2e-5, rtol=2e-5)

    def test_even_layer_routing_between_full_and_none(self, lm):
        """Even-layer routing (Fig. 7) must differ from teacher less than
        all-layer routing at the same low capacity."""
        cfg, tspec, rspec, P, R, tokens = lm
        lt = _teacher_logits(lm)
        caps = jnp.asarray([0.3, 0.3, 0.5, 0.25], jnp.float32)
        even = jnp.asarray([1.0 if i % 2 == 0 else 0.0
                            for i in range(cfg.n_layers)])
        full = jnp.ones((cfg.n_layers,))
        d_even = jnp.abs(train.lm_elastic_forward(
            tspec, rspec, cfg, P, R, tokens, caps, even,
            jnp.float32(0.0), use_pallas=False)[0] - lt).mean()
        d_full = jnp.abs(train.lm_elastic_forward(
            tspec, rspec, cfg, P, R, tokens, caps, full,
            jnp.float32(0.0), use_pallas=False)[0] - lt).mean()
        assert float(d_even) <= float(d_full) + 1e-6
        assert float(d_full) > 1e-4  # routing at low capacity does change things

    def test_lora_is_noop_at_init(self, lm):
        """LoRA B = 0 at init -> rank>0 elastic == rank-0 elastic."""
        cfg, tspec, rspec, P, R, tokens = lm
        rspec0 = params.lm_router_spec(cfg, lora_rank=0)
        # copy shared router entries from R into a rank-0 vector
        R0 = np.zeros((rspec0.total,), np.float32)
        Rnp = np.asarray(R)
        for name, _, _ in rspec0.entries:
            o0, s0 = rspec0.offsets[name], rspec0.shapes[name]
            o1 = rspec.offsets[name]
            n = int(np.prod(s0)) if s0 else 1
            R0[o0:o0 + n] = Rnp[o1:o1 + n]
        caps = jnp.asarray([0.6, 0.6, 0.5, 0.5], jnp.float32)
        full_l = jnp.ones((cfg.n_layers,))
        a = train.lm_elastic_forward(tspec, rspec, cfg, P, R, tokens, caps,
                                     full_l, jnp.float32(0.0),
                                     use_pallas=False)[0]
        b = train.lm_elastic_forward(tspec, rspec0, cfg, P, jnp.asarray(R0),
                                     tokens, caps, full_l, jnp.float32(0.0),
                                     use_pallas=False, lora_rank=0)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)

    def test_serve_cap1_equals_teacher(self, lm):
        cfg, tspec, _, P, _, tokens = lm
        rspec0 = params.lm_router_spec(cfg, lora_rank=0)
        R0 = rspec0.init_flat(jax.random.PRNGKey(3))
        lt = _teacher_logits(lm)
        ls = train.lm_serve_forward(tspec, rspec0, cfg, P, R0, tokens, 1.0)
        np.testing.assert_allclose(np.asarray(ls), np.asarray(lt),
                                   atol=2e-5, rtol=2e-5)


class TestRoutingBehaviour:
    def test_mask_counts_respect_capacity(self, lm):
        cfg, tspec, rspec, P, R, tokens = lm
        full_l = jnp.ones((cfg.n_layers,))
        caps = jnp.asarray([0.5, 0.25, 0.5, 0.5], jnp.float32)
        out = train.lm_elastic_forward(tspec, rspec, cfg, P, R, tokens, caps,
                                       full_l, jnp.float32(0.0),
                                       use_pallas=False)
        m_mha, m_mlp = np.asarray(out[4]), np.asarray(out[5])
        t = cfg.seq_len
        assert np.all(m_mha.sum(axis=-1) == int(np.ceil(0.5 * t)))
        assert np.all(m_mlp.sum(axis=-1) == int(np.ceil(0.25 * t)))

    def test_pruning_monotone_on_average(self, lm):
        """Fig. 2 probe: more pruned heads -> CE never improves much."""
        cfg, tspec, _, P, _, tokens = lm
        full_l = jnp.ones((cfg.n_layers,))
        ces = []
        rng = np.random.default_rng(0)
        for n_prune in (0, 2, 4):
            vals = []
            for _ in range(3):
                hm = np.ones((cfg.n_layers, cfg.n_heads), np.float32)
                flat = rng.choice(cfg.n_layers * cfg.n_heads, n_prune,
                                  replace=False)
                hm.reshape(-1)[flat] = 0.0
                _, ce = train.lm_teacher_forward(
                    tspec, cfg, P, tokens, jnp.asarray(hm), full_l, full_l)
                vals.append(float(ce))
            ces.append(np.mean(vals))
        assert ces[0] <= ces[2] + 0.05

    def test_distill_step_moves_router_not_nan(self, lm):
        cfg, tspec, rspec, P, R, tokens = lm
        m = jnp.zeros_like(R)
        v = jnp.zeros_like(R)
        caps = jnp.asarray([0.75, 0.75, 0.5, 0.5], jnp.float32)
        full_l = jnp.ones((cfg.n_layers,))
        R2, m2, v2, met = train.lm_distill_step(
            tspec, rspec, cfg, P, P, R, m, v, jnp.int32(0),
            jnp.float32(1e-3), tokens, caps, full_l, jnp.float32(1.0))
        assert np.all(np.isfinite(np.asarray(met)))
        assert float(jnp.abs(R2 - R).max()) > 0.0
        assert np.all(np.isfinite(np.asarray(R2)))

    def test_distill_improves_distill_loss(self, lm):
        """A few steps of router training must reduce the distill loss."""
        cfg, tspec, rspec, P, R, tokens = lm
        m = jnp.zeros_like(R)
        v = jnp.zeros_like(R)
        caps = jnp.asarray([0.75, 0.75, 0.5, 0.5], jnp.float32)
        full_l = jnp.ones((cfg.n_layers,))
        first = None
        for i in range(30):
            R, m, v, met = train.lm_distill_step(
                tspec, rspec, cfg, P, P, R, m, v, jnp.int32(i),
                jnp.float32(3e-3), tokens, caps, full_l, jnp.float32(1.0))
            if first is None:
                first = float(met[0])
        assert float(met[0]) < first


class TestViT:
    @pytest.fixture(scope="class")
    def vit(self):
        cfg = configs.ViTConfig(name="vit_test", img_size=16, patch=4,
                                d_model=32, n_layers=2, n_heads=2, d_ff=64,
                                batch=2, dec_d_model=16, dec_layers=1,
                                dec_heads=2, dec_d_ff=32, n_experts=4)
        tspec = params.vit_teacher_spec(cfg)
        rspec = params.vit_router_spec(cfg)
        P = tspec.init_flat(jax.random.PRNGKey(0))
        R = rspec.init_flat(jax.random.PRNGKey(1))
        imgs = jax.random.uniform(
            jax.random.PRNGKey(2),
            (cfg.batch, cfg.img_size * cfg.img_size * cfg.channels))
        return cfg, tspec, rspec, P, R, imgs

    def test_bypass_cosine_is_one(self, vit):
        cfg, tspec, rspec, P, R, imgs = vit
        full_l = jnp.ones((cfg.n_layers,))
        out = train.vit_elastic_forward(tspec, rspec, cfg, P, R, imgs,
                                        CAPS1, full_l, jnp.float32(2.0),
                                        use_pallas=True)
        np.testing.assert_allclose(np.asarray(out[3]), 1.0, atol=1e-5)

    def test_distill_step_finite_and_moves(self, vit):
        cfg, tspec, rspec, P, R, imgs = vit
        m = jnp.zeros_like(R)
        v = jnp.zeros_like(R)
        caps = jnp.asarray([0.8, 0.5, 0.5, 0.5], jnp.float32)
        full_l = jnp.ones((cfg.n_layers,))
        R2, _, _, met = train.vit_distill_step(
            tspec, rspec, cfg, P, R, m, v, jnp.int32(0), jnp.float32(1e-3),
            imgs, caps, full_l)
        assert np.all(np.isfinite(np.asarray(met)))
        assert float(jnp.abs(R2 - R).max()) > 0.0


class TestVLM:
    @pytest.fixture(scope="class")
    def vlm(self):
        cfg = configs.VLMConfig(name="vlm_test", img_size=16, patch=4,
                                v_d_model=32, v_layers=2, v_heads=2,
                                v_d_ff=64, d_model=32, n_layers=2, n_heads=2,
                                d_ff=64, text_len=12, batch=2,
                                router_hidden=16)
        tspec = params.vlm_teacher_spec(cfg)
        P = tspec.init_flat(jax.random.PRNGKey(0))
        imgs = jax.random.uniform(
            jax.random.PRNGKey(1),
            (cfg.batch, cfg.img_size * cfg.img_size * cfg.channels))
        texts = jax.random.randint(jax.random.PRNGKey(2),
                                   (cfg.batch, cfg.text_len), 3, cfg.vocab)
        return cfg, tspec, P, imgs, texts

    @pytest.mark.parametrize("mlp_router", [False, True])
    def test_bypass_equals_teacher(self, vlm, mlp_router):
        cfg, tspec, P, imgs, texts = vlm
        rspec = params.vlm_router_spec(cfg, mlp_router=mlp_router)
        R = rspec.init_flat(jax.random.PRNGKey(3))
        lt, _ = train.vlm_teacher_forward(tspec, cfg, P, imgs, texts)
        out = train.vlm_elastic_forward(tspec, rspec, cfg, P, R, imgs, texts,
                                        jnp.float32(1.0), jnp.float32(2.0),
                                        mlp_router)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(lt),
                                   atol=2e-5, rtol=2e-5)

    def test_capacity_drops_image_tokens(self, vlm):
        cfg, tspec, P, imgs, texts = vlm
        rspec = params.vlm_router_spec(cfg)
        R = rspec.init_flat(jax.random.PRNGKey(3))
        out = train.vlm_elastic_forward(tspec, rspec, cfg, P, R, imgs, texts,
                                        jnp.float32(0.5), jnp.float32(0.0),
                                        False)
        mask = np.asarray(out[3])
        assert np.all(mask.sum(axis=-1) == int(np.ceil(0.5 * cfg.n_img_tokens)))

    def test_distill_step_finite(self, vlm):
        cfg, tspec, P, imgs, texts = vlm
        rspec = params.vlm_router_spec(cfg)
        R = rspec.init_flat(jax.random.PRNGKey(3))
        m = jnp.zeros_like(R)
        v = jnp.zeros_like(R)
        R2, _, _, met = train.vlm_distill_step(
            tspec, rspec, cfg, P, R, m, v, jnp.int32(0), jnp.float32(1e-3),
            imgs, texts, jnp.float32(0.6), jnp.float32(1.0), False)
        assert np.all(np.isfinite(np.asarray(met)))
        assert float(jnp.abs(R2 - R).max()) > 0.0
